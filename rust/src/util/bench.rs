//! Hand-rolled benchmark harness (offline stand-in for `criterion`).
//!
//! Each `cargo bench` target is a plain binary (`harness = false`) that
//! uses [`Bench`] to time closures with warmup + repeated measurement and
//! print a stable, parseable report: one `row:`-prefixed line per
//! configuration, matching the tables/figures in EXPERIMENTS.md.

use std::time::Instant;

/// Timing summary over `reps` measured runs.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub reps: usize,
}

impl Timing {
    pub fn per(&self, n: usize) -> f64 {
        self.mean_s / n as f64
    }
}

/// Time `f` (warmup runs then measured reps). Returns per-run stats.
pub fn time<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / reps as f64;
    let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>()
        / reps.max(2) as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    Timing { mean_s: mean, std_s: var.sqrt(), min_s: min, reps }
}

/// Report sink: prints aligned `row:` lines and remembers them so a bench
/// can emit a machine-readable JSON block at the end.
pub struct Bench {
    pub name: &'static str,
    rows: Vec<(String, Vec<(String, String)>)>,
}

impl Bench {
    pub fn new(name: &'static str) -> Self {
        println!("=== bench: {name} ===");
        Bench { name, rows: Vec::new() }
    }

    /// Add one result row: label plus (column, value) pairs.
    pub fn row(&mut self, label: &str, cols: &[(&str, String)]) {
        let cols: Vec<(String, String)> =
            cols.iter().map(|(k, v)| (k.to_string(), v.clone())).collect();
        let line = cols
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join("  ");
        println!("row: {label:<28} {line}");
        self.rows.push((label.to_string(), cols));
    }

    /// Emit the whole report as one JSON line (for EXPERIMENTS.md
    /// tooling). With `ZMC_BENCH_JSON_DIR` set, the same document is
    /// also written to `<dir>/BENCH_<name>.json` — CI's bench-smoke job
    /// uploads these as workflow artifacts so the perf trajectory
    /// accumulates per push.
    pub fn finish(self) {
        use crate::util::json::Json;
        use std::collections::BTreeMap;

        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|(label, cols)| {
                let mut m = BTreeMap::new();
                m.insert("label".to_string(), Json::Str(label.clone()));
                for (k, v) in cols {
                    let j = v
                        .parse::<f64>()
                        .map(Json::Num)
                        .unwrap_or_else(|_| Json::Str(v.clone()));
                    m.insert(k.clone(), j);
                }
                Json::Obj(m)
            })
            .collect();
        let mut top = BTreeMap::new();
        top.insert("bench".to_string(), Json::Str(self.name.to_string()));
        top.insert("rows".to_string(), Json::Arr(rows));
        let doc = Json::Obj(top).to_string();
        println!("json: {doc}");
        if let Ok(dir) = std::env::var("ZMC_BENCH_JSON_DIR") {
            if !dir.is_empty() {
                write_json_report(std::path::Path::new(&dir), self.name, &doc);
            }
        }
    }
}

/// Write one bench report to `<dir>/BENCH_<name>.json` (best effort:
/// a failure warns on stderr rather than aborting the bench).
fn write_json_report(dir: &std::path::Path, name: &str, doc: &str) {
    let path = dir.join(format!("BENCH_{name}.json"));
    let write = std::fs::create_dir_all(dir)
        .and_then(|()| std::fs::write(&path, format!("{doc}\n")));
    if let Err(e) = write {
        eprintln!("warn: writing {}: {e}", path.display());
    }
}

/// Format seconds human-readably (µs/ms/s).
pub fn fmt_s(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_counts_reps() {
        let mut n = 0;
        let t = time(2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(t.reps, 5);
        assert!(t.min_s <= t.mean_s + 1e-12);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_s(2e-6).ends_with("us"));
        assert!(fmt_s(2e-3).ends_with("ms"));
        assert!(fmt_s(2.0).ends_with('s'));
    }

    #[test]
    fn bench_rows_to_json() {
        let mut b = Bench::new("unit");
        b.row("r1", &[("x", "1.5".into()), ("y", "abc".into())]);
        assert_eq!(b.rows.len(), 1);
        b.finish();
    }

    #[test]
    fn bench_json_report_file_written() {
        // the env-var plumbing is a one-line read in finish(); the file
        // write is tested directly to avoid mutating process-global env
        // from a multithreaded test binary
        let dir = std::env::temp_dir()
            .join(format!("zmc_bench_json_{}", std::process::id()));
        write_json_report(&dir, "unit_file", "{\"bench\":\"unit_file\"}");
        let path = dir.join("BENCH_unit_file.json");
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"bench\""), "{text}");
        assert!(text.contains("unit_file"));
        // missing parent is handled; unwritable paths only warn
        write_json_report(&dir.join("nested/deeper"), "x", "{}");
        assert!(dir.join("nested/deeper/BENCH_x.json").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
