//! Minimal JSON parser/serializer (RFC 8259 subset sufficient for the
//! artifact manifest and job configs: no surrogate-pair escapes).
//!
//! In-tree because the offline vendor set has no serde_json; ~200 lines,
//! fully unit-tested below, fuzzed by `util::proptest` round-trips.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Numbers are kept as f64 (adequate for manifest
/// shapes/counters; 2^53 exceeds any field we serialize).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj.path("a", "b")` == `obj["a"]["b"]` or None.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    // ---- lossless f64 wire encoding -------------------------------------

    /// Encode an `f64` losslessly for the wire: finite values become
    /// JSON numbers (the serializer prints the shortest round-tripping
    /// decimal, so every finite bit pattern survives, including
    /// `-0.0`), non-finite values become the string tokens `"NaN"` /
    /// `"inf"` / `"-inf"` — JSON has no number syntax for them. Decode
    /// with [`Json::wire_f64`]. NaN payload bits collapse to the
    /// canonical quiet NaN on the way back.
    pub fn from_f64(v: f64) -> Json {
        if v.is_finite() {
            Json::Num(v)
        } else if v.is_nan() {
            Json::Str("NaN".into())
        } else if v > 0.0 {
            Json::Str("inf".into())
        } else {
            Json::Str("-inf".into())
        }
    }

    /// Decode a [`Json::from_f64`] value: a plain number or one of the
    /// non-finite string tokens.
    pub fn wire_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            Json::Str(s) => match s.as_str() {
                "NaN" => Some(f64::NAN),
                "inf" => Some(f64::INFINITY),
                "-inf" => Some(f64::NEG_INFINITY),
                _ => None,
            },
            _ => None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.into() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        self.ws();
        let mut out = Vec::new();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                    self.ws();
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        self.ws();
        let mut out = BTreeMap::new();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            out.insert(key, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                    self.ws();
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self
                        .peek()
                        .ok_or_else(|| self.err("unterminated escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // copy the full UTF-8 sequence starting at c
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    if start + len > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    self.i = start + len;
                    let s = std::str::from_utf8(&self.b[start..self.i])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("bad number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    /// Compact serialization (used for job specs, bench reports).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if *n == 0.0 && n.is_sign_negative() {
                    // "-0" keeps the sign bit; the integer path below
                    // would print "0" and lose it on re-parse
                    write!(f, "-0")
                } else if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => {
                            write!(f, "\\u{:04x}", c as u32)?
                        }
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(
            Json::parse(r#""a\nb""#).unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.path(&["a"]).unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""é\tA π""#).unwrap();
        assert_eq!(v.as_str(), Some("é\tA π"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] x").is_err());
        assert!(Json::parse("'single'").is_err());
        assert!(Json::parse("{1: 2}").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"constants":{"MAX_DIM":8},"exe":["a",1.5,true,null]}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn negative_zero_keeps_its_sign() {
        assert_eq!(Json::Num(-0.0).to_string(), "-0");
        let back = Json::parse("-0").unwrap().as_f64().unwrap();
        assert!(back == 0.0 && back.is_sign_negative());
        assert_eq!(Json::Num(0.0).to_string(), "0");
    }

    #[test]
    fn f64_wire_tokens() {
        assert_eq!(Json::from_f64(f64::NAN).to_string(), "\"NaN\"");
        assert_eq!(Json::from_f64(f64::INFINITY).to_string(), "\"inf\"");
        assert_eq!(
            Json::from_f64(f64::NEG_INFINITY).to_string(),
            "\"-inf\""
        );
        assert_eq!(Json::from_f64(1.5), Json::Num(1.5));
        assert!(Json::Str("garbage".into()).wire_f64().is_none());
        assert!(Json::Null.wire_f64().is_none());
    }

    #[test]
    fn f64_wire_roundtrip_bits() {
        crate::util::proptest::check(0xB17E, 2000, |g| {
            let v = f64::from_bits(g.next_u64());
            let parsed =
                Json::parse(&Json::from_f64(v).to_string()).unwrap();
            let back = parsed.wire_f64().unwrap();
            if v.is_nan() {
                assert!(back.is_nan());
            } else {
                assert_eq!(back.to_bits(), v.to_bits(), "{v:?}");
            }
        });
    }

    #[test]
    fn accessor_type_mismatches() {
        let v = Json::parse(r#"{"n": 1.25}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), None);
        assert_eq!(v.get("n").unwrap().as_str(), None);
        assert_eq!(v.get("missing"), None);
        assert_eq!(Json::Num(8.0).as_usize(), Some(8));
    }
}
