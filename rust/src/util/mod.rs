//! Small in-tree utilities that would normally be external crates.
//!
//! The build environment is fully offline with only the PJRT bridge's
//! dependency set vendored, so JSON parsing ([`json`]), property-based
//! testing ([`proptest`]) and the bench harness ([`bench`]) are
//! implemented here rather than pulled from crates.io.

pub mod bench;
pub mod json;
pub mod proptest;
