//! Closed-form integrals for every benchmark family — the ground truth
//! each experiment checks its MC estimates against.

use std::f64::consts::PI;

/// ∫ a·cos(k·x) + b·sin(k·x) dx over the box `bounds` (Fig. 1 family).
///
/// With z = Π_d (e^{i k_d h_d} − e^{i k_d l_d}) / (i k_d)
/// (factor h_d − l_d when k_d = 0):  I = a·Re z + b·Im z.
pub fn harmonic_box(k: &[f64], a: f64, b: f64, bounds: &[(f64, f64)]) -> f64 {
    assert_eq!(k.len(), bounds.len());
    // complex product as (re, im)
    let (mut re, mut im) = (1.0f64, 0.0f64);
    for (kd, (lo, hi)) in k.iter().zip(bounds) {
        let (fr, fi) = if kd.abs() < 1e-300 {
            (hi - lo, 0.0)
        } else {
            // (e^{i k h} - e^{i k l}) / (i k)
            let (sh, ch) = (kd * hi).sin_cos();
            let (sl, cl) = (kd * lo).sin_cos();
            // numerator: (ch - cl) + i (sh - sl); divide by i k:
            // 1/(ik) = -i/k  →  (x + iy)·(-i/k) = (y - i x)/k
            ((sh - sl) / kd, -(ch - cl) / kd)
        };
        let nre = re * fr - im * fi;
        im = re * fi + im * fr;
        re = nre;
    }
    a * re + b * im
}

/// Fig. 1 integrand n: k = ((n+50)/2π)·𝟙₄ over [0,1]⁴, a=b=1.
pub fn fig1_truth(n: u32) -> f64 {
    let kn = (n as f64 + 50.0) / (2.0 * PI);
    harmonic_box(
        &[kn; 4],
        1.0,
        1.0,
        &[(0.0, 1.0), (0.0, 1.0), (0.0, 1.0), (0.0, 1.0)],
    )
}

/// ∫ |x₁ + x₂| over [0,1]² (= 1, positive everywhere) and the general
/// Eq. (2) first family a·|x1+x2| over [0,1]²: a · 1.
pub fn eq2_abs2(a: f64) -> f64 {
    a * 1.0
}

/// ∫ |x₁ + x₂ − x₃| dx over [0,1]³ (Eq. 2 second family): b · 7/12.
///
/// With s = x₁+x₂−x₃: E|s| = E s + 2·E max(x₃−x₁−x₂, 0)
/// = 1/2 + 2·∫₀¹ z³/6 dz = 1/2 + 1/12 = 7/12 (cross-checked against
/// midpoint quadrature in the tests below).
pub fn eq2_abs3(b: f64) -> f64 {
    b * 7.0 / 12.0
}

/// ∫ x₁^p over [0,1]^D = 1/(p+1) (any D; other dims integrate to 1).
pub fn monomial(p: f64) -> f64 {
    1.0 / (p + 1.0)
}

/// Genz "oscillatory": f(x) = cos(2π u + Σ c_d x_d) over [0,1]^D.
pub fn genz_oscillatory(u: f64, c: &[f64]) -> f64 {
    // ∫ = Re[ e^{i 2π u} Π (e^{i c_d} − 1)/(i c_d) ]
    let (mut re, mut im) = ((2.0 * PI * u).cos(), (2.0 * PI * u).sin());
    for &cd in c {
        let (fr, fi) = if cd.abs() < 1e-300 {
            (1.0, 0.0)
        } else {
            (cd.sin() / cd, -(cd.cos() - 1.0) / cd)
        };
        let nre = re * fr - im * fi;
        im = re * fi + im * fr;
        re = nre;
    }
    re
}

/// Genz "product peak": f(x) = Π 1/(c_d⁻² + (x_d − w_d)²) over [0,1]^D.
pub fn genz_product_peak(c: &[f64], w: &[f64]) -> f64 {
    c.iter()
        .zip(w)
        .map(|(&cd, &wd)| cd * ((cd * (1.0 - wd)).atan() + (cd * wd).atan()))
        .product()
}

/// Genz "Gaussian": f(x) = exp(−Σ c_d²(x_d − w_d)²) over [0,1]^D.
pub fn genz_gaussian(c: &[f64], w: &[f64]) -> f64 {
    c.iter()
        .zip(w)
        .map(|(&cd, &wd)| {
            (PI.sqrt() / (2.0 * cd))
                * (erf(cd * (1.0 - wd)) + erf(cd * wd))
        })
        .product()
}

/// erf via Abramowitz–Stegun 7.1.26 (|err| ≤ 1.5e-7 — fine for 6σ gates).
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t
            - 0.284496736)
            * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Gen};

    /// Brute-force midpoint quadrature for cross-checks (low-D only).
    fn quad<F: Fn(&[f64]) -> f64>(f: F, dims: usize, n: usize) -> f64 {
        let mut total = 0.0;
        let mut idx = vec![0usize; dims];
        let cells = n.pow(dims as u32);
        for c in 0..cells {
            let mut rem = c;
            for d in 0..dims {
                idx[d] = rem % n;
                rem /= n;
            }
            let x: Vec<f64> =
                idx.iter().map(|&i| (i as f64 + 0.5) / n as f64).collect();
            total += f(&x);
        }
        total / cells as f64
    }

    #[test]
    fn harmonic_1d_exact() {
        // ∫₀¹ cos(2x) = sin(2)/2 ; ∫₀¹ sin(2x) = (1−cos 2)/2
        let c = harmonic_box(&[2.0], 1.0, 0.0, &[(0.0, 1.0)]);
        assert!((c - (2.0f64).sin() / 2.0).abs() < 1e-14);
        let s = harmonic_box(&[2.0], 0.0, 1.0, &[(0.0, 1.0)]);
        assert!((s - (1.0 - (2.0f64).cos()) / 2.0).abs() < 1e-14);
    }

    #[test]
    fn harmonic_k_zero_gives_volume() {
        let v = harmonic_box(&[0.0, 0.0], 1.0, 0.0, &[(0.0, 2.0), (1.0, 4.0)]);
        assert!((v - 6.0).abs() < 1e-14);
    }

    #[test]
    fn harmonic_matches_quadrature() {
        let k = [1.3, -0.7];
        let truth =
            harmonic_box(&k, 0.8, -0.4, &[(0.0, 1.0), (0.0, 1.0)]);
        let q = quad(
            |x| {
                let p = k[0] * x[0] + k[1] * x[1];
                0.8 * p.cos() - 0.4 * p.sin()
            },
            2,
            400,
        );
        assert!((truth - q).abs() < 1e-5, "{truth} vs {q}");
    }

    #[test]
    fn harmonic_random_boxes_match_quadrature() {
        check(11, 10, |g: &mut Gen| {
            let k = [g.range_f64(-4.0, 4.0), g.range_f64(-4.0, 4.0)];
            let lo0 = g.range_f64(-1.0, 0.5);
            let lo1 = g.range_f64(-1.0, 0.5);
            let bounds = [
                (lo0, lo0 + g.range_f64(0.1, 1.5)),
                (lo1, lo1 + g.range_f64(0.1, 1.5)),
            ];
            let (a, b) = (g.range_f64(-2.0, 2.0), g.range_f64(-2.0, 2.0));
            let truth = harmonic_box(&k, a, b, &bounds);
            let vol: f64 =
                bounds.iter().map(|(l, h)| h - l).product();
            let q = vol
                * quad(
                    |u| {
                        let x0 = bounds[0].0
                            + (bounds[0].1 - bounds[0].0) * u[0];
                        let x1 = bounds[1].0
                            + (bounds[1].1 - bounds[1].0) * u[1];
                        let p = k[0] * x0 + k[1] * x1;
                        a * p.cos() + b * p.sin()
                    },
                    2,
                    300,
                );
            assert!((truth - q).abs() < 1e-3, "{truth} vs {q}");
        });
    }

    #[test]
    fn fig1_values_small() {
        // n→∞ ⇒ oscillation ⇒ integral → 0; all |I| ≤ vol = 1
        for n in [1, 50, 100] {
            let v = fig1_truth(n);
            assert!(v.abs() < 1.0, "n={n}: {v}");
        }
        // sanity vs quadrature at n=1 (k≈8.117)
        let kn = 51.0 / (2.0 * PI);
        let q = quad(
            |x| {
                let p = kn * (x[0] + x[1] + x[2] + x[3]);
                p.cos() + p.sin()
            },
            4,
            40,
        );
        assert!((fig1_truth(1) - q).abs() < 2e-3);
    }

    #[test]
    fn eq2_matches_quadrature() {
        let q2 = quad(|x| (x[0] + x[1]).abs(), 2, 600);
        assert!((eq2_abs2(1.0) - q2).abs() < 1e-4);
        let q3 = quad(|x| (x[0] + x[1] - x[2]).abs(), 3, 120);
        assert!((eq2_abs3(1.0) - q3).abs() < 1e-4, "{q3}");
    }

    #[test]
    fn genz_match_quadrature() {
        let c = [1.5, 0.8];
        let w = [0.3, 0.6];
        let qo = quad(
            |x| (2.0 * PI * 0.25 + c[0] * x[0] + c[1] * x[1]).cos(),
            2,
            400,
        );
        assert!((genz_oscillatory(0.25, &c) - qo).abs() < 1e-5);
        let qp = quad(
            |x| {
                (1.0 / (c[0].powi(-2) + (x[0] - w[0]).powi(2)))
                    * (1.0 / (c[1].powi(-2) + (x[1] - w[1]).powi(2)))
            },
            2,
            600,
        );
        assert!(
            (genz_product_peak(&c, &w) - qp).abs() / qp < 1e-4,
            "{} vs {qp}",
            genz_product_peak(&c, &w)
        );
        let qg = quad(
            |x| {
                (-(c[0] * c[0] * (x[0] - w[0]).powi(2)
                    + c[1] * c[1] * (x[1] - w[1]).powi(2)))
                .exp()
            },
            2,
            400,
        );
        assert!((genz_gaussian(&c, &w) - qg).abs() < 1e-4);
    }

    #[test]
    fn erf_reference_values() {
        // A&S 7.1.26 carries |err| <= 1.5e-7; gate at 2e-7.
        assert!((erf(0.0)).abs() < 2e-7);
        assert!((erf(1.0) - 0.8427007929).abs() < 2e-7);
        assert!((erf(-1.0) + 0.8427007929).abs() < 2e-7);
        assert!((erf(3.0) - 0.9999779095).abs() < 2e-7);
    }

    #[test]
    fn monomial_truth() {
        assert_eq!(monomial(2.0), 1.0 / 3.0);
        assert_eq!(monomial(0.0), 1.0);
    }
}
