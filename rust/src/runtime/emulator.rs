//! In-process CPU device emulator — the default execution backend when
//! the `pjrt` feature is off.
//!
//! The build environment has no PJRT plugin and no network, so instead
//! of stubbing execution out, this module interprets the three artifact
//! kinds with the **same sampling and evaluation semantics as the
//! Pallas kernels**: Philox-4x32-10 counter addressing via
//! [`StreamKey::point`] (bit-identical streams), f32 affine domain
//! mapping, f32 bytecode evaluation through [`BatchInterp`], and
//! per-function `(sum f, sum f^2)` moment outputs in the exact layouts the
//! manifest declares. It is the same mirror the runtime integration
//! tests check real artifacts against — see DESIGN.md "Substitutions".
//!
//! Compilation still goes through the per-worker cache in
//! [`crate::runtime::device::DeviceRuntime`] and is counted in the
//! [`Registry`](crate::runtime::registry::Registry) ledger, so the
//! engine's warm-cache behaviour is observable with or without PJRT.

use anyhow::{anyhow, bail, Result};

use crate::abi::{MAX_PARAM, MAX_PROG};
use crate::runtime::launch::Value;
use crate::runtime::registry::{ExeKind, ExeSpec};
use crate::sampler::StreamKey;
use crate::vm::interp::BatchInterp;
use crate::vm::opcodes::Op;
use crate::vm::program::{Instr, Program};

/// Samples per interpreter batch (mirrors the device tile trade-off).
const CHUNK: usize = 2048;

/// A "compiled" executable for the emulator: validation happened, the
/// kind is frozen. (Programs arrive per launch in the input tensors,
/// exactly as on the device, so there is nothing else to lower.)
#[derive(Debug, Clone)]
pub struct EmuExe {
    kind: ExeKind,
}

impl EmuExe {
    pub fn compile(spec: &ExeSpec) -> Result<EmuExe> {
        if !spec.hlo_text.contains("HloModule") {
            bail!("{}: not an HLO module", spec.name);
        }
        Ok(EmuExe { kind: spec.kind })
    }

    /// Execute one launch; `inputs` were already validated against the
    /// spec's tensor signatures by the caller.
    pub fn execute(&self, spec: &ExeSpec, inputs: &[Value]) -> Result<Vec<f32>> {
        match self.kind {
            ExeKind::VmMulti => run_vm_multi(spec, inputs),
            ExeKind::Harmonic => run_harmonic(spec, inputs),
            ExeKind::Stratified => run_stratified(spec, inputs),
        }
    }
}

fn u32s<'a>(v: &'a Value, what: &str) -> Result<&'a [u32]> {
    match v {
        Value::U32(x) => Ok(x),
        _ => Err(anyhow!("emulator: input '{what}' is not u32")),
    }
}

fn i32s<'a>(v: &'a Value, what: &str) -> Result<&'a [i32]> {
    match v {
        Value::I32(x) => Ok(x),
        _ => Err(anyhow!("emulator: input '{what}' is not i32")),
    }
}

fn f32s<'a>(v: &'a Value, what: &str) -> Result<&'a [f32]> {
    match v {
        Value::F32(x) => Ok(x),
        _ => Err(anyhow!("emulator: input '{what}' is not f32")),
    }
}

/// Reassemble a validated [`Program`] from one row of device arrays.
fn decode_program(
    ops: &[i32],
    iargs: &[i32],
    fargs: &[f32],
    plen: usize,
) -> Result<Program> {
    if plen > ops.len() {
        bail!("emulator: program length {plen} exceeds row width");
    }
    let mut instrs = Vec::with_capacity(plen);
    for p in 0..plen {
        let op = Op::from_code(ops[p])
            .ok_or_else(|| anyhow!("emulator: bad opcode {}", ops[p]))?;
        instrs.push(Instr { op, iarg: iargs[p], farg: fargs[p] });
    }
    Program::new(instrs).map_err(|e| anyhow!("emulator: invalid program: {e}"))
}

/// Chunked `(sum f, sum f^2)` of `prog` over `samples` draws of `key`
/// starting at counter `base`, with the device's f32 affine map
/// `x = lo + (hi - lo) * u` per dimension. Accumulates in f64 like the
/// CPU baseline (absorbs f32 partial error over large S).
#[allow(clippy::too_many_arguments)]
fn moment_sums(
    prog: &Program,
    key: &StreamKey,
    base: u32,
    samples: usize,
    lo: &[f32],
    hi: &[f32],
    theta: &[f32],
    interp: &mut BatchInterp,
    buf: &mut [f32],
) -> (f64, f64) {
    let dims = prog.dims;
    let mut xt: Vec<Vec<f32>> = vec![vec![0f32; CHUNK]; dims];
    let (mut sum, mut sumsq) = (0f64, 0f64);
    let mut done = 0usize;
    while done < samples {
        let n = (samples - done).min(CHUNK);
        for i in 0..n {
            let u = key.point(base.wrapping_add((done + i) as u32), dims);
            for (d, row) in xt.iter_mut().enumerate() {
                row[i] = lo[d] + (hi[d] - lo[d]) * u[d];
            }
        }
        interp.eval(prog, &xt, theta, n, buf);
        for &v in &buf[..n] {
            sum += v as f64;
            sumsq += (v as f64) * (v as f64);
        }
        done += n;
    }
    (sum, sumsq)
}

/// `vm_multi`: N independent bytecode integrands per launch.
/// Output layout `f32[N, 2]`: `[f*2] = sum f`, `[f*2+1] = sum f^2`; null
/// slots (plen 0) stay exactly zero.
fn run_vm_multi(spec: &ExeSpec, inputs: &[Value]) -> Result<Vec<f32>> {
    let seed = u32s(&inputs[0], "seed")?;
    let ctr = u32s(&inputs[1], "ctr")?;
    let streams = u32s(&inputs[2], "streams")?;
    let plens = i32s(&inputs[3], "plens")?;
    let ops = i32s(&inputs[4], "ops")?;
    let iargs = i32s(&inputs[5], "iargs")?;
    let fargs = f32s(&inputs[6], "fargs")?;
    let theta = f32s(&inputs[7], "theta")?;
    let lo = f32s(&inputs[8], "lo")?;
    let hi = f32s(&inputs[9], "hi")?;
    let (n, d, p) = (spec.n_fns, spec.dims, MAX_PROG);

    let mut out = vec![0f32; n * 2];
    let mut interp = BatchInterp::new(CHUNK);
    let mut buf = vec![0f32; CHUNK];
    for f in 0..n {
        let plen = plens[f].max(0) as usize;
        if plen == 0 {
            continue; // null slot
        }
        let prog = decode_program(
            &ops[f * p..(f + 1) * p],
            &iargs[f * p..(f + 1) * p],
            &fargs[f * p..(f + 1) * p],
            plen,
        )?;
        if prog.dims > d {
            bail!("emulator: fn {f} reads x{} but exe has {d} dims", prog.dims);
        }
        let key = StreamKey {
            seed: [seed[0], seed[1]],
            stream: streams[f],
            trial: ctr[1],
        };
        let (s, q) = moment_sums(
            &prog,
            &key,
            ctr[0],
            spec.samples,
            &lo[f * d..(f + 1) * d],
            &hi[f * d..(f + 1) * d],
            &theta[f * MAX_PARAM..(f + 1) * MAX_PARAM],
            &mut interp,
            &mut buf,
        );
        out[f * 2] = s as f32;
        out[f * 2 + 1] = q as f32;
    }
    Ok(out)
}

/// `harmonic`: up to N functions `a cos(k.x) + b sin(k.x)` over one
/// shared sample tile. Output layout `f32[2, N]`: row 0 sums, row 1
/// sums of squares; unused slots (a = b = 0) stay exactly zero.
fn run_harmonic(spec: &ExeSpec, inputs: &[Value]) -> Result<Vec<f32>> {
    let seed = u32s(&inputs[0], "seed")?;
    let ctr = u32s(&inputs[1], "ctr")?; // [base, stream, trial]
    let k = f32s(&inputs[2], "k")?;
    let a = f32s(&inputs[3], "a")?;
    let b = f32s(&inputs[4], "b")?;
    let lo = f32s(&inputs[5], "lo")?;
    let hi = f32s(&inputs[6], "hi")?;
    let (n, d) = (spec.n_fns, spec.dims);

    let live: Vec<usize> =
        (0..n).filter(|&f| a[f] != 0.0 || b[f] != 0.0).collect();
    let key = StreamKey {
        seed: [seed[0], seed[1]],
        stream: ctr[1],
        trial: ctr[2],
    };
    let mut sums = vec![0f64; n];
    let mut sqs = vec![0f64; n];
    let mut x = vec![0f32; d];
    for i in 0..spec.samples {
        let u = key.point(ctr[0].wrapping_add(i as u32), d);
        for dd in 0..d {
            x[dd] = lo[dd] + (hi[dd] - lo[dd]) * u[dd];
        }
        for &f in &live {
            let mut phase = 0f32;
            for dd in 0..d {
                phase += k[f * d + dd] * x[dd];
            }
            let v = (a[f] * phase.cos() + b[f] * phase.sin()) as f64;
            sums[f] += v;
            sqs[f] += v * v;
        }
    }
    let mut out = vec![0f32; 2 * n];
    for f in 0..n {
        out[f] = sums[f] as f32;
        out[n + f] = sqs[f] as f32;
    }
    Ok(out)
}

/// `stratified`: one shared program over a batch of cubes, one Philox
/// stream per cube. Output layout `f32[C, 2]`.
fn run_stratified(spec: &ExeSpec, inputs: &[Value]) -> Result<Vec<f32>> {
    let seed = u32s(&inputs[0], "seed")?;
    let ctr = u32s(&inputs[1], "ctr")?; // [base, trial]
    let streams = u32s(&inputs[2], "streams")?;
    let plen = i32s(&inputs[3], "plen")?[0].max(0) as usize;
    let ops = i32s(&inputs[4], "ops")?;
    let iargs = i32s(&inputs[5], "iargs")?;
    let fargs = f32s(&inputs[6], "fargs")?;
    let theta = f32s(&inputs[7], "theta")?;
    let cl = f32s(&inputs[8], "cl")?;
    let ch = f32s(&inputs[9], "ch")?;
    let (c, d) = (spec.n_cubes, spec.dims);

    if plen == 0 {
        bail!("emulator: stratified launch with empty program");
    }
    let prog = decode_program(ops, iargs, fargs, plen)?;
    if prog.dims > d {
        bail!("emulator: program reads x{} but exe has {d} dims", prog.dims);
    }
    let mut out = vec![0f32; c * 2];
    let mut interp = BatchInterp::new(CHUNK);
    let mut buf = vec![0f32; CHUNK];
    for ci in 0..c {
        let key = StreamKey {
            seed: [seed[0], seed[1]],
            stream: streams[ci],
            trial: ctr[1],
        };
        let (s, q) = moment_sums(
            &prog,
            &key,
            ctr[0],
            spec.samples,
            &cl[ci * d..(ci + 1) * d],
            &ch[ci * d..(ci + 1) * d],
            theta,
            &mut interp,
            &mut buf,
        );
        out[ci * 2] = s as f32;
        out[ci * 2 + 1] = q as f32;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::Expr;
    use crate::runtime::launch::{
        harmonic_inputs, stratified_inputs, vm_multi_inputs, RngCtr, VmFn,
    };
    use crate::runtime::registry::Registry;

    fn exec(reg: &Registry, name: &str, inputs: &[Value]) -> Vec<f32> {
        let spec = reg.get(name).unwrap();
        EmuExe::compile(spec).unwrap().execute(spec, inputs).unwrap()
    }

    #[test]
    fn constant_integrand_sums_exactly() {
        let reg = Registry::emulated();
        let exe = reg.get("vm_multi_f8_s4096").unwrap();
        let f = VmFn {
            program: Expr::parse("1").unwrap().compile().unwrap(),
            theta: vec![],
            bounds: vec![(0.0, 1.0)],
            stream: 0,
        };
        let rng = RngCtr { seed: [1, 2], base: 0, trial: 0 };
        let inputs =
            vm_multi_inputs(exe, rng, std::slice::from_ref(&f)).unwrap();
        let out = exec(&reg, &exe.name, &inputs);
        assert_eq!(out[0], exe.samples as f32);
        assert_eq!(out[1], exe.samples as f32);
        // null slots exactly zero
        assert!(out[2..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn vm_matches_streamkey_mirror() {
        let reg = Registry::emulated();
        let exe = reg.get("vm_multi_f8_s4096").unwrap();
        let f = VmFn {
            program: Expr::parse("x1*x2").unwrap().compile().unwrap(),
            theta: vec![],
            bounds: vec![(0.0, 1.0), (0.0, 2.0)],
            stream: 9,
        };
        let rng = RngCtr { seed: [7, 8], base: 4096, trial: 3 };
        let inputs =
            vm_multi_inputs(exe, rng, std::slice::from_ref(&f)).unwrap();
        let out = exec(&reg, &exe.name, &inputs);

        // independent scalar mirror over the same stream
        let key = StreamKey { seed: [7, 8], stream: 9, trial: 3 };
        let (mut s, mut q) = (0f64, 0f64);
        for i in 0..exe.samples {
            let u = key.point(4096u32.wrapping_add(i as u32), 2);
            let x0 = u[0]; // lo=0, hi=1
            let x1 = 2.0f32 * u[1];
            let v = (x0 * x1) as f64;
            s += v;
            q += v * v;
        }
        assert!((out[0] as f64 - s).abs() < 1e-3 * s.max(1.0), "{}", out[0]);
        assert!((out[1] as f64 - q).abs() < 1e-3 * q.max(1.0));
    }

    #[test]
    fn harmonic_zero_wavevector_is_constant() {
        let reg = Registry::emulated();
        let exe = reg.get("harmonic_s8192_n128").unwrap();
        // k = 0 -> f = a*cos(0) + b*sin(0) = a
        let rng = RngCtr { seed: [3, 4], base: 0, trial: 0 };
        let inputs = harmonic_inputs(
            exe,
            rng,
            5,
            &[vec![0.0, 0.0]],
            &[2.5],
            &[1.0],
            &[0.0, 0.0],
            &[1.0, 1.0],
        )
        .unwrap();
        let out = exec(&reg, &exe.name, &inputs);
        let s = exe.samples as f32;
        assert!((out[0] - 2.5 * s).abs() < 1e-2 * s);
        assert!((out[exe.n_fns] - 6.25 * s).abs() < 1e-1 * s);
        // padded function slots exactly zero
        assert_eq!(out[1], 0.0);
    }

    #[test]
    fn stratified_unit_program_counts_samples() {
        let reg = Registry::emulated();
        let exe = reg.get("stratified_c16_s256").unwrap();
        let prog = Expr::parse("1").unwrap().compile().unwrap();
        let cubes: Vec<(Vec<f64>, Vec<f64>)> = (0..16)
            .map(|i| (vec![i as f64 / 16.0], vec![(i + 1) as f64 / 16.0]))
            .collect();
        let streams: Vec<u32> = (0..16).collect();
        let rng = RngCtr { seed: [5, 6], base: 0, trial: 0 };
        let inputs =
            stratified_inputs(exe, rng, &prog, &[], &cubes, &streams)
                .unwrap();
        let out = exec(&reg, &exe.name, &inputs);
        for c in 0..16 {
            assert_eq!(out[c * 2], exe.samples as f32, "cube {c}");
            assert_eq!(out[c * 2 + 1], exe.samples as f32);
        }
    }

    #[test]
    fn chunked_counters_tile_seamlessly() {
        // launches at base 0 and base=samples must form one logical
        // stream: merged sums equal a single double-length mirror pass
        let reg = Registry::emulated();
        let exe = reg.get("vm_multi_f8_s4096").unwrap();
        let f = VmFn {
            program: Expr::parse("x1").unwrap().compile().unwrap(),
            theta: vec![],
            bounds: vec![(0.0, 1.0)],
            stream: 0,
        };
        let mut total = 0f64;
        for chunk in 0..2u32 {
            let rng = RngCtr {
                seed: [9, 9],
                base: chunk * exe.samples as u32,
                trial: 0,
            };
            let inputs =
                vm_multi_inputs(exe, rng, std::slice::from_ref(&f)).unwrap();
            let out = exec(&reg, &exe.name, &inputs);
            total += out[0] as f64;
        }
        let key = StreamKey { seed: [9, 9], stream: 0, trial: 0 };
        let mut s = 0f64;
        for i in 0..2 * exe.samples {
            s += key.point(i as u32, 1)[0] as f64;
        }
        assert!((total - s).abs() < 1e-3 * s, "{total} vs {s}");
    }

    #[test]
    fn compile_rejects_non_hlo() {
        let mut spec = Registry::emulated()
            .get("vm_multi_f8_s4096")
            .unwrap()
            .clone();
        spec.hlo_text = "garbage".into();
        assert!(EmuExe::compile(&spec).is_err());
    }
}
