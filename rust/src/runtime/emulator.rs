//! In-process CPU device emulator — the default execution backend when
//! the `pjrt` feature is off.
//!
//! The build environment has no PJRT plugin and no network, so instead
//! of stubbing execution out, this module interprets the three artifact
//! kinds with the **same sampling and evaluation semantics as the
//! Pallas kernels**: Philox-4x32-10 counter addressing via
//! [`StreamKey::point`] (bit-identical streams), f32 affine domain
//! mapping, f32 bytecode evaluation, and per-function
//! `(sum f, sum f^2)` moment outputs in the exact layouts the manifest
//! declares. It is the same mirror the runtime integration tests check
//! real artifacts against — see DESIGN.md "Substitutions".
//!
//! ## The optimizing pipeline
//!
//! Program launches run through one of three [`ExecTier`]s (selected
//! per worker, default [`ExecTier::Fused`], overridable process-wide
//! with `ZMC_EMU_TIER={naive,plan,fused}`):
//!
//! * **fused** — each distinct program row is lowered once per worker
//!   into a [`FusedPlan`] and executed as a single blocked
//!   generate/evaluate/reduce pass (SIMD Philox lane blocks, in-kernel
//!   f64 moment epilogue — see [`crate::vm::fused`]);
//! * **plan** — the columnar [`ExecPlan`] pipeline
//!   ([`crate::vm::plan`]) over materialized sample columns, retained
//!   as the fused tier's structured oracle;
//! * **naive** — the pre-plan [`BatchInterp`] stack interpreter
//!   ([`moment_sums_naive`]), the original bit-exact oracle (the
//!   deprecated `ZMC_EMU_NAIVE=1` still selects it).
//!
//! All three produce **bit-identical** moment payloads: same Philox
//! blocks, same per-lane f32 operation sequence, same sequential f64
//! accumulation order. Lowered rows live in per-worker [`EmuState`]
//! LRU caches (hits/misses ledgered in the [`Registry`] next to the
//! compile counter — plan and fused tiers each have their own ledger
//! rows — and surfaced in engine
//! [`Metrics`](crate::coordinator::progress::Metrics)); steady-state
//! launches perform no heap allocation beyond the output payload.
//!
//! Compilation still goes through the per-worker cache in
//! [`crate::runtime::device::DeviceRuntime`] and is counted in the
//! [`Registry`] ledger, so the engine's warm-cache behaviour is
//! observable with or without PJRT.

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{anyhow, bail, Result};

use crate::abi::{MAX_DIM, MAX_PARAM, MAX_PROG};
use crate::runtime::launch::Value;
use crate::runtime::registry::{ExeKind, ExeSpec, Registry};
use crate::runtime::ExecTier;
use crate::sampler::StreamKey;
use crate::vm::fused::{FusedPlan, FusedScratch};
use crate::vm::interp::BatchInterp;
use crate::vm::opcodes::Op;
use crate::vm::plan::{ExecPlan, PlanScratch};
use crate::vm::program::{Instr, Program};

/// Samples per interpreter batch (mirrors the device tile trade-off).
const CHUNK: usize = 2048;

/// Plans kept per worker before LRU eviction (each is a few hundred
/// bytes; 256 comfortably covers the multifunction batches the engine
/// shards onto one worker).
const PLAN_CACHE_CAP: usize = 256;

/// A "compiled" executable for the emulator: validation happened, the
/// kind is frozen. (Programs arrive per launch in the input tensors,
/// exactly as on the device; lowering them to [`ExecPlan`]s is the
/// per-worker plan cache's job.)
#[derive(Debug, Clone)]
pub struct EmuExe {
    kind: ExeKind,
}

impl EmuExe {
    pub fn compile(spec: &ExeSpec) -> Result<EmuExe> {
        if !spec.hlo_text.contains("HloModule") {
            bail!("{}: not an HLO module", spec.name);
        }
        Ok(EmuExe { kind: spec.kind })
    }

    /// Execute one launch; `inputs` were already validated against the
    /// spec's tensor signatures by the caller. `state` is the calling
    /// worker's reusable scratch + plan cache; `registry` receives the
    /// plan-ledger events.
    pub fn execute(
        &self,
        spec: &ExeSpec,
        inputs: &[Value],
        state: &mut EmuState,
        registry: &Registry,
    ) -> Result<Vec<f32>> {
        match self.kind {
            ExeKind::VmMulti => run_vm_multi(spec, inputs, state, registry),
            ExeKind::Harmonic => run_harmonic(spec, inputs, state),
            ExeKind::Stratified => {
                run_stratified(spec, inputs, state, registry)
            }
        }
    }
}

// ---------------------------------------------------------------------
// Per-worker state: scratch arenas + lowered-row caches

/// One cached lowering: the exact program row it came from (collision
/// guard) plus an LRU stamp.
struct RowEntry<T> {
    ops: Vec<i32>,
    iargs: Vec<i32>,
    fbits: Vec<u32>,
    val: T,
    stamp: u64,
}

/// Which [`Registry`] ledger a row cache reports to.
#[derive(Clone, Copy)]
enum RowLedger {
    Plan,
    Fused,
}

/// Per-worker LRU keyed by [`row_hash`], shared by the plan and fused
/// tiers — each tier owns one cache with its own ledger rows and event
/// counters, but the hashing, exact-row collision guard and
/// min-stamp eviction logic exist once.
struct RowCache<T> {
    entries: HashMap<u64, RowEntry<T>>,
    clock: u64,
    ledger: RowLedger,
    // events since the last `take_events`
    hits: u64,
    misses: u64,
}

impl<T: Clone> RowCache<T> {
    fn new(ledger: RowLedger) -> Self {
        RowCache {
            entries: HashMap::new(),
            clock: 0,
            ledger,
            hits: 0,
            misses: 0,
        }
    }

    fn len(&self) -> usize {
        self.entries.len()
    }

    fn take_events(&mut self) -> (u64, u64) {
        (std::mem::take(&mut self.hits), std::mem::take(&mut self.misses))
    }

    /// Fetch (or lower via `lower`) the value for one program row.
    /// Cache hits allocate nothing and skip decoding entirely; every
    /// miss is ledgered in the [`Registry`].
    fn get_or_lower(
        &mut self,
        ops: &[i32],
        iargs: &[i32],
        fargs: &[f32],
        plen: usize,
        registry: &Registry,
        lower: impl FnOnce() -> Result<T>,
    ) -> Result<T> {
        let key = row_hash(ops, iargs, fargs, plen);
        self.clock += 1;
        if let Some(e) = self.entries.get_mut(&key) {
            if e.ops.len() == plen
                && e.ops[..] == ops[..plen]
                && e.iargs[..] == iargs[..plen]
                && e.fbits.iter().zip(&fargs[..plen]).all(|(&b, f)| b == f.to_bits())
            {
                e.stamp = self.clock;
                self.hits += 1;
                match self.ledger {
                    RowLedger::Plan => registry.note_plan_hit(),
                    RowLedger::Fused => registry.note_fused_hit(),
                }
                return Ok(e.val.clone());
            }
            // 64-bit hash collision: evict the stale entry and relower
            self.entries.remove(&key);
        }
        self.misses += 1;
        match self.ledger {
            RowLedger::Plan => registry.note_plan_lower(),
            RowLedger::Fused => registry.note_fused_lower(),
        }
        let val = lower()?;
        if self.entries.len() >= PLAN_CACHE_CAP {
            let evict = self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(&k, _)| k);
            if let Some(k) = evict {
                self.entries.remove(&k);
            }
        }
        self.entries.insert(
            key,
            RowEntry {
                ops: ops[..plen].to_vec(),
                iargs: iargs[..plen].to_vec(),
                fbits: fargs[..plen].iter().map(|f| f.to_bits()).collect(),
                val: val.clone(),
                stamp: self.clock,
            },
        );
        Ok(val)
    }
}

/// Reusable per-worker execution state. Owned by the worker's
/// [`DeviceRuntime`](crate::runtime::device::DeviceRuntime) for the
/// engine's lifetime, so steady-state launches are allocation-free:
/// sample columns, the plan register arena, the interpreter stack and
/// the harmonic accumulators are all hoisted here.
pub struct EmuState {
    /// Unit-cube uniform columns (plan path input).
    ucols: Vec<Vec<f32>>,
    /// Mapped sample columns (naive-path input), built lazily.
    xt: Vec<Vec<f32>>,
    /// Per-chunk evaluation output row.
    buf: Vec<f32>,
    scratch: PlanScratch,
    /// Fused-tier scratch (lane blocks + register arena).
    fscratch: FusedScratch,
    /// Stack interpreter for the naive oracle path, built lazily.
    interp: Option<BatchInterp>,
    plans: RowCache<Rc<ExecPlan>>,
    fused: RowCache<Rc<FusedPlan>>,
    /// Which execution tier program launches run through.
    tier: ExecTier,
    // harmonic scratch
    hsums: Vec<f64>,
    hsqs: Vec<f64>,
    hx: Vec<f32>,
    hlive: Vec<usize>,
}

impl Default for EmuState {
    fn default() -> Self {
        EmuState::new()
    }
}

impl EmuState {
    /// Worker state with the process-wide tier
    /// ([`ExecTier::from_env`]).
    pub fn new() -> Self {
        EmuState::with_tier(ExecTier::from_env())
    }

    /// Worker state pinned to `tier` (the Session builder's
    /// `execution_tier` plumbs through here via the device pool).
    pub fn with_tier(tier: ExecTier) -> Self {
        EmuState {
            ucols: vec![vec![0f32; CHUNK]; MAX_DIM],
            xt: Vec::new(),
            buf: vec![0f32; CHUNK],
            scratch: PlanScratch::new(CHUNK),
            fscratch: FusedScratch::new(),
            interp: None,
            plans: RowCache::new(RowLedger::Plan),
            fused: RowCache::new(RowLedger::Fused),
            tier,
            hsums: Vec::new(),
            hsqs: Vec::new(),
            hx: Vec::new(),
            hlive: Vec::new(),
        }
    }

    /// This worker's execution tier.
    pub fn tier(&self) -> ExecTier {
        self.tier
    }

    /// Lowered program rows currently cached by this worker (plan +
    /// fused tiers).
    pub fn cached_plans(&self) -> usize {
        self.plans.len() + self.fused.len()
    }

    /// Drain the plan-tier (hits, misses) accumulated since the last
    /// call — the engine backend folds these into its [`Metrics`]
    /// after each task.
    pub fn take_plan_events(&mut self) -> (u64, u64) {
        self.plans.take_events()
    }

    /// Fused-tier twin of [`EmuState::take_plan_events`].
    pub fn take_fused_events(&mut self) -> (u64, u64) {
        self.fused.take_events()
    }

    /// Lend out the naive-path buffers (interpreter stack + mapped
    /// sample columns), building them on first use. Both launch paths
    /// that fall back to the pre-plan interpreter share this so the
    /// lazy-init/restore choreography exists exactly once; give the
    /// buffers back with [`EmuState::restore_naive_buffers`].
    fn take_naive_buffers(&mut self) -> (BatchInterp, Vec<Vec<f32>>) {
        let interp =
            self.interp.take().unwrap_or_else(|| BatchInterp::new(CHUNK));
        let mut xt = std::mem::take(&mut self.xt);
        if xt.is_empty() {
            xt = vec![vec![0f32; CHUNK]; MAX_DIM];
        }
        (interp, xt)
    }

    fn restore_naive_buffers(&mut self, interp: BatchInterp, xt: Vec<Vec<f32>>) {
        self.interp = Some(interp);
        self.xt = xt;
    }

    /// Fetch (or decode + lower) the plan-tier lowering of one program
    /// row, ledgered via [`Registry::note_plan_lower`] /
    /// [`Registry::note_plan_hit`].
    fn plan_for(
        &mut self,
        ops: &[i32],
        iargs: &[i32],
        fargs: &[f32],
        plen: usize,
        registry: &Registry,
    ) -> Result<Rc<ExecPlan>> {
        self.plans.get_or_lower(ops, iargs, fargs, plen, registry, || {
            let prog = decode_program(ops, iargs, fargs, plen)?;
            Ok(Rc::new(ExecPlan::lower(&prog)))
        })
    }

    /// Fused-tier twin of [`EmuState::plan_for`], ledgered via
    /// [`Registry::note_fused_lower`] / [`Registry::note_fused_hit`].
    fn fused_for(
        &mut self,
        ops: &[i32],
        iargs: &[i32],
        fargs: &[f32],
        plen: usize,
        registry: &Registry,
    ) -> Result<Rc<FusedPlan>> {
        self.fused.get_or_lower(ops, iargs, fargs, plen, registry, || {
            let prog = decode_program(ops, iargs, fargs, plen)?;
            Ok(Rc::new(FusedPlan::new(ExecPlan::lower(&prog))))
        })
    }
}

/// FNV-1a over one padded program row's live prefix.
fn row_hash(ops: &[i32], iargs: &[i32], fargs: &[f32], plen: usize) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    let mut mix = |w: u32| {
        for b in w.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01B3);
        }
    };
    mix(plen as u32);
    for p in 0..plen.min(ops.len()) {
        mix(ops[p] as u32);
        mix(iargs[p] as u32);
        mix(fargs[p].to_bits());
    }
    h
}

fn u32s<'a>(v: &'a Value, what: &str) -> Result<&'a [u32]> {
    match v {
        Value::U32(x) => Ok(x),
        _ => Err(anyhow!("emulator: input '{what}' is not u32")),
    }
}

fn i32s<'a>(v: &'a Value, what: &str) -> Result<&'a [i32]> {
    match v {
        Value::I32(x) => Ok(x),
        _ => Err(anyhow!("emulator: input '{what}' is not i32")),
    }
}

fn f32s<'a>(v: &'a Value, what: &str) -> Result<&'a [f32]> {
    match v {
        Value::F32(x) => Ok(x),
        _ => Err(anyhow!("emulator: input '{what}' is not f32")),
    }
}

/// Reassemble a validated [`Program`] from one row of device arrays.
fn decode_program(
    ops: &[i32],
    iargs: &[i32],
    fargs: &[f32],
    plen: usize,
) -> Result<Program> {
    if plen > ops.len() {
        bail!("emulator: program length {plen} exceeds row width");
    }
    let mut instrs = Vec::with_capacity(plen);
    for p in 0..plen {
        let op = Op::from_code(ops[p])
            .ok_or_else(|| anyhow!("emulator: bad opcode {}", ops[p]))?;
        instrs.push(Instr { op, iarg: iargs[p], farg: fargs[p] });
    }
    Program::new(instrs).map_err(|e| anyhow!("emulator: invalid program: {e}"))
}

/// Chunked `(sum f, sum f^2)` of `prog` over `samples` draws of `key`
/// starting at counter `base`, with the device's f32 affine map
/// `x = lo + (hi - lo) * u` per dimension, through the **pre-plan stack
/// interpreter**. Accumulates in f64 like the CPU baseline (absorbs f32
/// partial error over large S). Retained as the bit-exact oracle for
/// [`moment_sums_plan`] and as the baseline the `vm_pipeline` bench
/// gates against.
#[allow(clippy::too_many_arguments)]
pub fn moment_sums_naive(
    prog: &Program,
    key: &StreamKey,
    base: u32,
    samples: usize,
    lo: &[f32],
    hi: &[f32],
    theta: &[f32],
    interp: &mut BatchInterp,
    xt: &mut [Vec<f32>],
    buf: &mut [f32],
) -> (f64, f64) {
    let dims = prog.dims;
    let chunk = interp.chunk().min(buf.len());
    let (mut sum, mut sumsq) = (0f64, 0f64);
    let mut done = 0usize;
    while done < samples {
        let n = (samples - done).min(chunk);
        for i in 0..n {
            let u = key.point(base.wrapping_add((done + i) as u32), dims);
            for (d, row) in xt.iter_mut().take(dims).enumerate() {
                row[i] = lo[d] + (hi[d] - lo[d]) * u[d];
            }
        }
        interp.eval(prog, xt, theta, n, buf);
        for &v in &buf[..n] {
            sum += v as f64;
            sumsq += (v as f64) * (v as f64);
        }
        done += n;
    }
    (sum, sumsq)
}

/// [`moment_sums_naive`] through the optimized [`ExecPlan`] pipeline:
/// uniforms are generated block-major into reusable columns, the affine
/// domain map is folded into the plan's sample loads, and the program
/// executes over the register arena. Bit-identical results — same
/// Philox blocks, same per-lane f32 operation sequence, same f64
/// accumulation order.
#[allow(clippy::too_many_arguments)]
pub fn moment_sums_plan(
    plan: &ExecPlan,
    key: &StreamKey,
    base: u32,
    samples: usize,
    lo: &[f32],
    hi: &[f32],
    theta: &[f32],
    ucols: &mut [Vec<f32>],
    scratch: &mut PlanScratch,
    buf: &mut [f32],
) -> (f64, f64) {
    let dims = plan.dims;
    let chunk = scratch.chunk().min(buf.len());
    let (mut sum, mut sumsq) = (0f64, 0f64);
    let mut done = 0usize;
    while done < samples {
        let n = (samples - done).min(chunk);
        key.fill_columns(base.wrapping_add(done as u32), n, dims, ucols);
        plan.run(ucols, lo, hi, theta, n, scratch, buf);
        for &v in &buf[..n] {
            sum += v as f64;
            sumsq += (v as f64) * (v as f64);
        }
        done += n;
    }
    (sum, sumsq)
}

/// `vm_multi`: N independent bytecode integrands per launch.
/// Output layout `f32[N, 2]`: `[f*2] = sum f`, `[f*2+1] = sum f^2`; null
/// slots (plen 0) stay exactly zero.
fn run_vm_multi(
    spec: &ExeSpec,
    inputs: &[Value],
    state: &mut EmuState,
    registry: &Registry,
) -> Result<Vec<f32>> {
    let seed = u32s(&inputs[0], "seed")?;
    let ctr = u32s(&inputs[1], "ctr")?;
    let streams = u32s(&inputs[2], "streams")?;
    let plens = i32s(&inputs[3], "plens")?;
    let ops = i32s(&inputs[4], "ops")?;
    let iargs = i32s(&inputs[5], "iargs")?;
    let fargs = f32s(&inputs[6], "fargs")?;
    let theta = f32s(&inputs[7], "theta")?;
    let lo = f32s(&inputs[8], "lo")?;
    let hi = f32s(&inputs[9], "hi")?;
    let (n, d, p) = (spec.n_fns, spec.dims, MAX_PROG);

    let mut out = vec![0f32; n * 2];
    for f in 0..n {
        let plen = plens[f].max(0) as usize;
        if plen == 0 {
            continue; // null slot
        }
        let key = StreamKey {
            seed: [seed[0], seed[1]],
            stream: streams[f],
            trial: ctr[1],
        };
        let row = f * p..(f + 1) * p;
        let (flo, fhi) = (&lo[f * d..(f + 1) * d], &hi[f * d..(f + 1) * d]);
        let fth = &theta[f * MAX_PARAM..(f + 1) * MAX_PARAM];
        let (s, q) = match state.tier {
            ExecTier::Naive => {
                let prog = decode_program(
                    &ops[row.clone()],
                    &iargs[row.clone()],
                    &fargs[row],
                    plen,
                )?;
                check_dims(prog.dims, d, Some(f))?;
                let (mut interp, mut xt) = state.take_naive_buffers();
                let r = moment_sums_naive(
                    &prog,
                    &key,
                    ctr[0],
                    spec.samples,
                    flo,
                    fhi,
                    fth,
                    &mut interp,
                    &mut xt,
                    &mut state.buf,
                );
                state.restore_naive_buffers(interp, xt);
                r
            }
            ExecTier::Plan => {
                let plan = state.plan_for(
                    &ops[row.clone()],
                    &iargs[row.clone()],
                    &fargs[row],
                    plen,
                    registry,
                )?;
                check_dims(plan.dims, d, Some(f))?;
                moment_sums_plan(
                    &plan,
                    &key,
                    ctr[0],
                    spec.samples,
                    flo,
                    fhi,
                    fth,
                    &mut state.ucols,
                    &mut state.scratch,
                    &mut state.buf,
                )
            }
            ExecTier::Fused => {
                let fp = state.fused_for(
                    &ops[row.clone()],
                    &iargs[row.clone()],
                    &fargs[row],
                    plen,
                    registry,
                )?;
                check_dims(fp.plan().dims, d, Some(f))?;
                fp.moment_sums(
                    &key,
                    ctr[0],
                    spec.samples as u32,
                    flo,
                    fhi,
                    fth,
                    &mut state.fscratch,
                )
            }
        };
        out[f * 2] = s as f32;
        out[f * 2 + 1] = q as f32;
    }
    Ok(out)
}

/// Reject programs reading more sample dims than the exe provides.
/// `fn_idx` names the offending `vm_multi` row; `None` means the
/// launch's single shared program (stratified).
fn check_dims(
    prog_dims: usize,
    exe_dims: usize,
    fn_idx: Option<usize>,
) -> Result<()> {
    if prog_dims > exe_dims {
        match fn_idx {
            Some(f) => bail!(
                "emulator: fn {f} reads x{prog_dims} but exe has {exe_dims} dims"
            ),
            None => bail!(
                "emulator: program reads x{prog_dims} but exe has {exe_dims} dims"
            ),
        }
    }
    Ok(())
}

/// `harmonic`: up to N functions `a cos(k.x) + b sin(k.x)` over one
/// shared sample tile. Output layout `f32[2, N]`: row 0 sums, row 1
/// sums of squares; unused slots (a = b = 0) stay exactly zero.
fn run_harmonic(
    spec: &ExeSpec,
    inputs: &[Value],
    state: &mut EmuState,
) -> Result<Vec<f32>> {
    let seed = u32s(&inputs[0], "seed")?;
    let ctr = u32s(&inputs[1], "ctr")?; // [base, stream, trial]
    let k = f32s(&inputs[2], "k")?;
    let a = f32s(&inputs[3], "a")?;
    let b = f32s(&inputs[4], "b")?;
    let lo = f32s(&inputs[5], "lo")?;
    let hi = f32s(&inputs[6], "hi")?;
    let (n, d) = (spec.n_fns, spec.dims);

    // per-worker scratch: resized once, zeroed per launch
    state.hlive.clear();
    state.hlive.extend((0..n).filter(|&f| a[f] != 0.0 || b[f] != 0.0));
    state.hsums.clear();
    state.hsums.resize(n, 0f64);
    state.hsqs.clear();
    state.hsqs.resize(n, 0f64);
    state.hx.clear();
    state.hx.resize(d, 0f32);

    let key = StreamKey {
        seed: [seed[0], seed[1]],
        stream: ctr[1],
        trial: ctr[2],
    };
    for i in 0..spec.samples {
        let u = key.point(ctr[0].wrapping_add(i as u32), d);
        for dd in 0..d {
            state.hx[dd] = lo[dd] + (hi[dd] - lo[dd]) * u[dd];
        }
        for &f in &state.hlive {
            let mut phase = 0f32;
            for dd in 0..d {
                phase += k[f * d + dd] * state.hx[dd];
            }
            let v = (a[f] * phase.cos() + b[f] * phase.sin()) as f64;
            state.hsums[f] += v;
            state.hsqs[f] += v * v;
        }
    }
    let mut out = vec![0f32; 2 * n];
    for f in 0..n {
        out[f] = state.hsums[f] as f32;
        out[n + f] = state.hsqs[f] as f32;
    }
    Ok(out)
}

/// `stratified`: one shared program over a batch of cubes, one Philox
/// stream per cube. Output layout `f32[C, 2]`. The shared program is
/// decoded + lowered once (plan-cache hit for every cube after the
/// first, and across launches).
fn run_stratified(
    spec: &ExeSpec,
    inputs: &[Value],
    state: &mut EmuState,
    registry: &Registry,
) -> Result<Vec<f32>> {
    let seed = u32s(&inputs[0], "seed")?;
    let ctr = u32s(&inputs[1], "ctr")?; // [base, trial]
    let streams = u32s(&inputs[2], "streams")?;
    let plen = i32s(&inputs[3], "plen")?[0].max(0) as usize;
    let ops = i32s(&inputs[4], "ops")?;
    let iargs = i32s(&inputs[5], "iargs")?;
    let fargs = f32s(&inputs[6], "fargs")?;
    let theta = f32s(&inputs[7], "theta")?;
    let cl = f32s(&inputs[8], "cl")?;
    let ch = f32s(&inputs[9], "ch")?;
    let (c, d) = (spec.n_cubes, spec.dims);

    if plen == 0 {
        bail!("emulator: stratified launch with empty program");
    }
    let mut out = vec![0f32; c * 2];
    let cube_key = |ci: usize| StreamKey {
        seed: [seed[0], seed[1]],
        stream: streams[ci],
        trial: ctr[1],
    };
    match state.tier {
        ExecTier::Naive => {
            let prog = decode_program(ops, iargs, fargs, plen)?;
            check_dims(prog.dims, d, None)?;
            let (mut interp, mut xt) = state.take_naive_buffers();
            for ci in 0..c {
                let (s, q) = moment_sums_naive(
                    &prog,
                    &cube_key(ci),
                    ctr[0],
                    spec.samples,
                    &cl[ci * d..(ci + 1) * d],
                    &ch[ci * d..(ci + 1) * d],
                    theta,
                    &mut interp,
                    &mut xt,
                    &mut state.buf,
                );
                out[ci * 2] = s as f32;
                out[ci * 2 + 1] = q as f32;
            }
            state.restore_naive_buffers(interp, xt);
        }
        ExecTier::Plan => {
            let plan = state.plan_for(ops, iargs, fargs, plen, registry)?;
            check_dims(plan.dims, d, None)?;
            for ci in 0..c {
                let (s, q) = moment_sums_plan(
                    &plan,
                    &cube_key(ci),
                    ctr[0],
                    spec.samples,
                    &cl[ci * d..(ci + 1) * d],
                    &ch[ci * d..(ci + 1) * d],
                    theta,
                    &mut state.ucols,
                    &mut state.scratch,
                    &mut state.buf,
                );
                out[ci * 2] = s as f32;
                out[ci * 2 + 1] = q as f32;
            }
        }
        ExecTier::Fused => {
            let fp = state.fused_for(ops, iargs, fargs, plen, registry)?;
            check_dims(fp.plan().dims, d, None)?;
            for ci in 0..c {
                let (s, q) = fp.moment_sums(
                    &cube_key(ci),
                    ctr[0],
                    spec.samples as u32,
                    &cl[ci * d..(ci + 1) * d],
                    &ch[ci * d..(ci + 1) * d],
                    theta,
                    &mut state.fscratch,
                );
                out[ci * 2] = s as f32;
                out[ci * 2 + 1] = q as f32;
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::Expr;
    use crate::runtime::launch::{
        harmonic_inputs, stratified_inputs, vm_multi_inputs, RngCtr, VmFn,
    };
    use crate::runtime::registry::Registry;

    fn exec(reg: &Registry, name: &str, inputs: &[Value]) -> Vec<f32> {
        let spec = reg.get(name).unwrap();
        let mut state = EmuState::new();
        EmuExe::compile(spec)
            .unwrap()
            .execute(spec, inputs, &mut state, reg)
            .unwrap()
    }

    #[test]
    fn constant_integrand_sums_exactly() {
        let reg = Registry::emulated();
        let exe = reg.get("vm_multi_f8_s4096").unwrap();
        let f = VmFn {
            program: Expr::parse("1").unwrap().compile().unwrap(),
            theta: vec![],
            bounds: vec![(0.0, 1.0)],
            stream: 0,
        };
        let rng = RngCtr { seed: [1, 2], base: 0, trial: 0 };
        let inputs =
            vm_multi_inputs(exe, rng, std::slice::from_ref(&f)).unwrap();
        let out = exec(&reg, &exe.name, &inputs);
        assert_eq!(out[0], exe.samples as f32);
        assert_eq!(out[1], exe.samples as f32);
        // null slots exactly zero
        assert!(out[2..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn vm_matches_streamkey_mirror() {
        let reg = Registry::emulated();
        let exe = reg.get("vm_multi_f8_s4096").unwrap();
        let f = VmFn {
            program: Expr::parse("x1*x2").unwrap().compile().unwrap(),
            theta: vec![],
            bounds: vec![(0.0, 1.0), (0.0, 2.0)],
            stream: 9,
        };
        let rng = RngCtr { seed: [7, 8], base: 4096, trial: 3 };
        let inputs =
            vm_multi_inputs(exe, rng, std::slice::from_ref(&f)).unwrap();
        let out = exec(&reg, &exe.name, &inputs);

        // independent scalar mirror over the same stream
        let key = StreamKey { seed: [7, 8], stream: 9, trial: 3 };
        let (mut s, mut q) = (0f64, 0f64);
        for i in 0..exe.samples {
            let u = key.point(4096u32.wrapping_add(i as u32), 2);
            let x0 = u[0]; // lo=0, hi=1
            let x1 = 2.0f32 * u[1];
            let v = (x0 * x1) as f64;
            s += v;
            q += v * v;
        }
        assert!((out[0] as f64 - s).abs() < 1e-3 * s.max(1.0), "{}", out[0]);
        assert!((out[1] as f64 - q).abs() < 1e-3 * q.max(1.0));
    }

    #[test]
    fn all_tiers_bit_identical_launches() {
        // the whole launch surface — vm_multi with params/bounds and
        // stratified cubes — must produce the exact same payload bits
        // through the fused pass, the plan pipeline and the pre-plan
        // interpreter
        let reg = Registry::emulated();
        let exe = reg.get("vm_multi_f8_s4096").unwrap();
        let fns: Vec<VmFn> = (0..5)
            .map(|i| VmFn {
                program: Expr::parse("cos(2*pi*p0 + p1*x1) + x2*x2*p2")
                    .unwrap()
                    .compile()
                    .unwrap(),
                theta: vec![0.1 * i as f64, 1.0 + i as f64, 0.5],
                bounds: vec![(-1.0, 1.0), (0.0, 2.0)],
                stream: 100 + i as u32,
            })
            .collect();
        let rng = RngCtr { seed: [3, 9], base: 8192, trial: 2 };
        let inputs = vm_multi_inputs(exe, rng, &fns).unwrap();
        let spec = reg.get(&exe.name).unwrap();
        let emu = EmuExe::compile(spec).unwrap();
        let mut states = [
            EmuState::with_tier(ExecTier::Fused),
            EmuState::with_tier(ExecTier::Plan),
            EmuState::with_tier(ExecTier::Naive),
        ];
        let outs: Vec<Vec<u32>> = states
            .iter_mut()
            .map(|s| {
                emu.execute(spec, &inputs, s, &reg)
                    .unwrap()
                    .iter()
                    .map(|v| v.to_bits())
                    .collect()
            })
            .collect();
        assert_eq!(outs[0], outs[1], "fused vs plan");
        assert_eq!(outs[0], outs[2], "fused vs naive");

        let sexe = reg.get("stratified_c16_s256").unwrap();
        let prog = Expr::parse("exp(0-p0*x1)*x2").unwrap().compile().unwrap();
        let cubes: Vec<(Vec<f64>, Vec<f64>)> = (0..16)
            .map(|i| {
                (vec![i as f64 / 16.0, 0.0], vec![(i + 1) as f64 / 16.0, 2.0])
            })
            .collect();
        let streams: Vec<u32> = (0..16).collect();
        let srng = RngCtr { seed: [5, 6], base: 64, trial: 1 };
        let sinputs =
            stratified_inputs(sexe, srng, &prog, &[1.5], &cubes, &streams)
                .unwrap();
        let semu = EmuExe::compile(sexe).unwrap();
        let souts: Vec<Vec<u32>> = states
            .iter_mut()
            .map(|s| {
                semu.execute(sexe, &sinputs, s, &reg)
                    .unwrap()
                    .iter()
                    .map(|v| v.to_bits())
                    .collect()
            })
            .collect();
        assert_eq!(souts[0], souts[1], "fused vs plan (stratified)");
        assert_eq!(souts[0], souts[2], "fused vs naive (stratified)");
    }

    #[test]
    fn plan_cache_hits_after_first_launch() {
        let reg = Registry::emulated();
        let exe = reg.get("vm_multi_f8_s4096").unwrap();
        let f = VmFn {
            program: Expr::parse("x1*x1 + p0").unwrap().compile().unwrap(),
            theta: vec![2.0],
            bounds: vec![(0.0, 1.0)],
            stream: 4,
        };
        let rng = RngCtr { seed: [1, 1], base: 0, trial: 0 };
        let inputs =
            vm_multi_inputs(exe, rng, std::slice::from_ref(&f)).unwrap();
        let spec = reg.get(&exe.name).unwrap();
        let emu = EmuExe::compile(spec).unwrap();
        let mut state = EmuState::with_tier(ExecTier::Plan);
        emu.execute(spec, &inputs, &mut state, &reg).unwrap();
        assert_eq!(state.cached_plans(), 1);
        assert_eq!(state.take_plan_events(), (0, 1));
        for _ in 0..3 {
            emu.execute(spec, &inputs, &mut state, &reg).unwrap();
        }
        assert_eq!(state.cached_plans(), 1);
        assert_eq!(state.take_plan_events(), (3, 0));
        // the plan tier never touches the fused cache or its events
        assert_eq!(state.take_fused_events(), (0, 0));
    }

    #[test]
    fn fused_cache_hits_after_first_launch() {
        // fused-tier mirror of the plan-cache test above, including the
        // registry's fused ledger rows
        let reg = Registry::emulated();
        let exe = reg.get("vm_multi_f8_s4096").unwrap();
        let f = VmFn {
            program: Expr::parse("x1*x1 + p0").unwrap().compile().unwrap(),
            theta: vec![2.0],
            bounds: vec![(0.0, 1.0)],
            stream: 4,
        };
        let rng = RngCtr { seed: [1, 1], base: 0, trial: 0 };
        let inputs =
            vm_multi_inputs(exe, rng, std::slice::from_ref(&f)).unwrap();
        let spec = reg.get(&exe.name).unwrap();
        let emu = EmuExe::compile(spec).unwrap();
        let mut state = EmuState::with_tier(ExecTier::Fused);
        assert_eq!(state.tier(), ExecTier::Fused);
        emu.execute(spec, &inputs, &mut state, &reg).unwrap();
        assert_eq!(state.cached_plans(), 1);
        assert_eq!(state.take_fused_events(), (0, 1));
        assert_eq!(reg.fused_lower_count(), 1);
        for _ in 0..3 {
            emu.execute(spec, &inputs, &mut state, &reg).unwrap();
        }
        assert_eq!(state.cached_plans(), 1);
        assert_eq!(state.take_fused_events(), (3, 0));
        assert_eq!(reg.fused_lower_count(), 1);
        assert_eq!(reg.fused_hit_count(), 3);
        // the fused tier never touches the plan cache or its events
        assert_eq!(state.take_plan_events(), (0, 0));
        assert_eq!(reg.plan_lower_count(), 0);
    }

    #[test]
    fn plan_cache_evicts_least_recently_used() {
        let reg = Registry::emulated();
        let mut state = EmuState::with_tier(ExecTier::Plan);
        // distinct single-constant programs: CONST i
        let mk = |i: usize| {
            let ops = vec![Op::CONST.code()];
            let iargs = vec![0i32];
            let fargs = vec![i as f32];
            (ops, iargs, fargs)
        };
        for i in 0..PLAN_CACHE_CAP + 10 {
            let (o, ia, fa) = mk(i);
            state.plan_for(&o, &ia, &fa, 1, &reg).unwrap();
        }
        assert_eq!(state.cached_plans(), PLAN_CACHE_CAP);
        // the most recent entry is still cached
        let (o, ia, fa) = mk(PLAN_CACHE_CAP + 9);
        state.take_plan_events();
        state.plan_for(&o, &ia, &fa, 1, &reg).unwrap();
        assert_eq!(state.take_plan_events(), (1, 0));
        // the oldest was evicted: re-lowering it is a miss
        let (o, ia, fa) = mk(0);
        state.plan_for(&o, &ia, &fa, 1, &reg).unwrap();
        assert_eq!(state.take_plan_events(), (0, 1));
    }

    #[test]
    fn harmonic_zero_wavevector_is_constant() {
        let reg = Registry::emulated();
        let exe = reg.get("harmonic_s8192_n128").unwrap();
        // k = 0 -> f = a*cos(0) + b*sin(0) = a
        let rng = RngCtr { seed: [3, 4], base: 0, trial: 0 };
        let inputs = harmonic_inputs(
            exe,
            rng,
            5,
            &[vec![0.0, 0.0]],
            &[2.5],
            &[1.0],
            &[0.0, 0.0],
            &[1.0, 1.0],
        )
        .unwrap();
        let out = exec(&reg, &exe.name, &inputs);
        let s = exe.samples as f32;
        assert!((out[0] - 2.5 * s).abs() < 1e-2 * s);
        assert!((out[exe.n_fns] - 6.25 * s).abs() < 1e-1 * s);
        // padded function slots exactly zero
        assert_eq!(out[1], 0.0);
    }

    #[test]
    fn stratified_unit_program_counts_samples() {
        let reg = Registry::emulated();
        let exe = reg.get("stratified_c16_s256").unwrap();
        let prog = Expr::parse("1").unwrap().compile().unwrap();
        let cubes: Vec<(Vec<f64>, Vec<f64>)> = (0..16)
            .map(|i| (vec![i as f64 / 16.0], vec![(i + 1) as f64 / 16.0]))
            .collect();
        let streams: Vec<u32> = (0..16).collect();
        let rng = RngCtr { seed: [5, 6], base: 0, trial: 0 };
        let inputs =
            stratified_inputs(exe, rng, &prog, &[], &cubes, &streams)
                .unwrap();
        let out = exec(&reg, &exe.name, &inputs);
        for c in 0..16 {
            assert_eq!(out[c * 2], exe.samples as f32, "cube {c}");
            assert_eq!(out[c * 2 + 1], exe.samples as f32);
        }
    }

    #[test]
    fn chunked_counters_tile_seamlessly() {
        // launches at base 0 and base=samples must form one logical
        // stream: merged sums equal a single double-length mirror pass
        let reg = Registry::emulated();
        let exe = reg.get("vm_multi_f8_s4096").unwrap();
        let f = VmFn {
            program: Expr::parse("x1").unwrap().compile().unwrap(),
            theta: vec![],
            bounds: vec![(0.0, 1.0)],
            stream: 0,
        };
        let mut total = 0f64;
        for chunk in 0..2u32 {
            let rng = RngCtr {
                seed: [9, 9],
                base: chunk * exe.samples as u32,
                trial: 0,
            };
            let inputs =
                vm_multi_inputs(exe, rng, std::slice::from_ref(&f)).unwrap();
            let out = exec(&reg, &exe.name, &inputs);
            total += out[0] as f64;
        }
        let key = StreamKey { seed: [9, 9], stream: 0, trial: 0 };
        let mut s = 0f64;
        for i in 0..2 * exe.samples {
            s += key.point(i as u32, 1)[0] as f64;
        }
        assert!((total - s).abs() < 1e-3 * s, "{total} vs {s}");
    }

    #[test]
    fn compile_rejects_non_hlo() {
        let mut spec = Registry::emulated()
            .get("vm_multi_f8_s4096")
            .unwrap()
            .clone();
        spec.hlo_text = "garbage".into();
        assert!(EmuExe::compile(&spec).is_err());
    }

    #[test]
    fn bad_opcode_still_rejected_via_plan_path() {
        let reg = Registry::emulated();
        let mut state = EmuState::new();
        let err = state
            .plan_for(&[999], &[0], &[0.0], 1, &reg)
            .unwrap_err()
            .to_string();
        assert!(err.contains("bad opcode"), "{err}");
        // same rejection through the fused tier's lowering
        let err = state
            .fused_for(&[999], &[0], &[0.0], 1, &reg)
            .unwrap_err()
            .to_string();
        assert!(err.contains("bad opcode"), "{err}");
    }
}
