//! Typed launch arguments for the three artifact kinds.
//!
//! A launch is described by a vector of [`Value`]s in manifest input
//! order; [`super::device::DeviceRuntime::execute`] checks each against
//! the executable's [`TensorSpec`](super::registry::TensorSpec) before
//! building PJRT literals, so shape/dtype bugs surface as errors at the
//! call site, not as garbage integrals.

use anyhow::{bail, Result};

use crate::abi::{MAX_PARAM, MAX_PROG};
use crate::runtime::registry::{DType, ExeSpec, TensorSpec};
use crate::vm::program::Program;

/// One input tensor's payload.
#[derive(Debug, Clone)]
pub enum Value {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
}

impl Value {
    pub fn dtype(&self) -> DType {
        match self {
            Value::F32(_) => DType::F32,
            Value::I32(_) => DType::I32,
            Value::U32(_) => DType::U32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Value::F32(v) => v.len(),
            Value::I32(v) => v.len(),
            Value::U32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn check(&self, spec: &TensorSpec) -> Result<()> {
        if self.dtype() != spec.dtype {
            bail!(
                "input '{}': dtype {:?} != manifest {:?}",
                spec.name,
                self.dtype(),
                spec.dtype
            );
        }
        if self.len() != spec.elements() {
            bail!(
                "input '{}': {} elements, manifest shape {:?} wants {}",
                spec.name,
                self.len(),
                spec.shape,
                spec.elements()
            );
        }
        Ok(())
    }
}

/// RNG addressing for one launch (chunked relaunches advance `base`).
#[derive(Debug, Clone, Copy)]
pub struct RngCtr {
    pub seed: [u32; 2],
    pub base: u32,
    pub trial: u32,
}

/// Build inputs for a `harmonic` artifact.
/// `k` is row-major `[n_fns][dims]`, padded to the exe's dims with 0
/// (k=0 dims contribute nothing to the phase).
#[allow(clippy::too_many_arguments)]
pub fn harmonic_inputs(
    exe: &ExeSpec,
    rng: RngCtr,
    stream: u32,
    k: &[Vec<f64>],
    a: &[f64],
    b: &[f64],
    lo: &[f64],
    hi: &[f64],
) -> Result<Vec<Value>> {
    let (n, d) = (exe.n_fns, exe.dims);
    if k.len() > n || a.len() != k.len() || b.len() != k.len() {
        bail!("harmonic: {} functions > capacity {n}", k.len());
    }
    if lo.len() > d || lo.len() != hi.len() {
        bail!("harmonic: bad bounds dims {}", lo.len());
    }
    let mut kf = vec![0f32; n * d];
    for (i, row) in k.iter().enumerate() {
        if row.len() > d {
            bail!("harmonic: k row {i} has {} dims > {d}", row.len());
        }
        for (j, &v) in row.iter().enumerate() {
            kf[i * d + j] = v as f32;
        }
    }
    let pad = |v: &[f64], fill: f32, len: usize| {
        let mut out = vec![fill; len];
        for (o, &x) in out.iter_mut().zip(v) {
            *o = x as f32;
        }
        out
    };
    // unused function slots keep a=b=0 so they contribute zeros;
    // padded dims get [0,1) bounds (any non-degenerate range works —
    // k=0 there makes the phase contribution vanish).
    Ok(vec![
        Value::U32(vec![rng.seed[0], rng.seed[1]]),
        Value::U32(vec![rng.base, stream, rng.trial]),
        Value::F32(kf),
        Value::F32(pad(a, 0.0, n)),
        Value::F32(pad(b, 0.0, n)),
        Value::F32(pad(lo, 0.0, d)),
        Value::F32(pad(hi, 1.0, d)),
    ])
}

/// Per-function payload for a `vm_multi` launch row.
#[derive(Debug, Clone)]
pub struct VmFn {
    pub program: Program,
    pub theta: Vec<f64>,
    pub bounds: Vec<(f64, f64)>,
    /// Globally unique Philox stream for this integrand.
    pub stream: u32,
}

/// Build inputs for a `vm_multi` artifact. Unused function slots get the
/// constant-0 program over [0,1]^D.
pub fn vm_multi_inputs(
    exe: &ExeSpec,
    rng: RngCtr,
    fns: &[VmFn],
) -> Result<Vec<Value>> {
    let (n, d, p) = (exe.n_fns, exe.dims, MAX_PROG);
    if fns.len() > n {
        bail!("vm_multi: {} functions > capacity {n}", fns.len());
    }
    let mut streams = vec![0u32; n];
    let mut plens = vec![0i32; n]; // 0 = null slot: VM loop skips it
    let mut ops = vec![0i32; n * p]; // HALT == 0 → null program
    let mut iargs = vec![0i32; n * p];
    let mut fargs = vec![0f32; n * p];
    let mut theta = vec![0f32; n * MAX_PARAM];
    let mut lo = vec![0f32; n * d];
    let mut hi = vec![1f32; n * d];
    for (i, f) in fns.iter().enumerate() {
        if f.bounds.len() > d {
            bail!("vm_multi: fn {i} has {} dims > {d}", f.bounds.len());
        }
        if f.theta.len() > MAX_PARAM {
            bail!("vm_multi: fn {i} has {} params", f.theta.len());
        }
        if f.program.dims > f.bounds.len() {
            bail!(
                "vm_multi: fn {i} reads x{} but only {} bounds given",
                f.program.dims,
                f.bounds.len()
            );
        }
        streams[i] = f.stream;
        plens[i] = f.program.len() as i32;
        let (o, ia, fa) = f.program.device_rows();
        ops[i * p..(i + 1) * p].copy_from_slice(&o);
        iargs[i * p..(i + 1) * p].copy_from_slice(&ia);
        fargs[i * p..(i + 1) * p].copy_from_slice(&fa);
        for (j, &t) in f.theta.iter().enumerate() {
            theta[i * MAX_PARAM + j] = t as f32;
        }
        for (j, &(l, h)) in f.bounds.iter().enumerate() {
            lo[i * d + j] = l as f32;
            hi[i * d + j] = h as f32;
        }
    }
    Ok(vec![
        Value::U32(vec![rng.seed[0], rng.seed[1]]),
        Value::U32(vec![rng.base, rng.trial]),
        Value::U32(streams),
        Value::I32(plens),
        Value::I32(ops),
        Value::I32(iargs),
        Value::F32(fargs),
        Value::F32(theta),
        Value::F32(lo),
        Value::F32(hi),
    ])
}

/// Build inputs for a `stratified` artifact: one shared program over a
/// batch of cubes. Unused cube slots get a degenerate [0,0] box (their
/// results are ignored by the caller).
pub fn stratified_inputs(
    exe: &ExeSpec,
    rng: RngCtr,
    program: &Program,
    theta: &[f64],
    cubes: &[(Vec<f64>, Vec<f64>)],
    streams: &[u32],
) -> Result<Vec<Value>> {
    let (c, d) = (exe.n_cubes, exe.dims);
    if cubes.len() > c {
        bail!("stratified: {} cubes > capacity {c}", cubes.len());
    }
    if streams.len() != cubes.len() {
        bail!("stratified: streams/cubes length mismatch");
    }
    let (ops, iargs, fargs) = program.device_rows();
    let mut th = vec![0f32; MAX_PARAM];
    for (j, &t) in theta.iter().enumerate() {
        th[j] = t as f32;
    }
    let mut cl = vec![0f32; c * d];
    let mut ch = vec![0f32; c * d];
    let mut st = vec![0u32; c];
    for (i, (clo, chi)) in cubes.iter().enumerate() {
        if clo.len() > d || clo.len() != chi.len() {
            bail!("stratified: cube {i} has bad dims");
        }
        st[i] = streams[i];
        for j in 0..clo.len() {
            cl[i * d + j] = clo[j] as f32;
            ch[i * d + j] = chi[j] as f32;
        }
        // pad unused dims to the unit interval so the program (which by
        // validation never reads them) samples harmlessly.
        for j in clo.len()..d {
            ch[i * d + j] = 1.0;
        }
    }
    Ok(vec![
        Value::U32(vec![rng.seed[0], rng.seed[1]]),
        Value::U32(vec![rng.base, rng.trial]),
        Value::U32(st),
        Value::I32(vec![program.len() as i32]),
        Value::I32(ops),
        Value::I32(iargs),
        Value::F32(fargs),
        Value::F32(th),
        Value::F32(cl),
        Value::F32(ch),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::registry::ExeKind;

    fn fake_exe(kind: ExeKind) -> ExeSpec {
        ExeSpec {
            name: "t".into(),
            kind,
            inputs: vec![],
            outputs: vec![],
            samples: 1024,
            n_fns: 4,
            n_cubes: 4,
            dims: 8,
            tile: 256,
            hlo_text: String::new(),
        }
    }

    #[test]
    fn harmonic_padding() {
        let exe = fake_exe(ExeKind::Harmonic);
        let rng = RngCtr { seed: [1, 2], base: 3, trial: 4 };
        let vals = harmonic_inputs(
            &exe,
            rng,
            9,
            &[vec![1.0, 2.0]],
            &[0.5],
            &[0.25],
            &[0.0, 0.0],
            &[1.0, 2.0],
        )
        .unwrap();
        assert_eq!(vals.len(), 7);
        match &vals[2] {
            Value::F32(k) => {
                assert_eq!(k.len(), 32);
                assert_eq!(&k[..3], &[1.0, 2.0, 0.0]);
                assert!(k[8..].iter().all(|&v| v == 0.0));
            }
            _ => panic!(),
        }
        match &vals[6] {
            Value::F32(hi) => assert_eq!(&hi[..3], &[1.0, 2.0, 1.0]),
            _ => panic!(),
        }
        match &vals[1] {
            Value::U32(c) => assert_eq!(c, &vec![3, 9, 4]),
            _ => panic!(),
        }
    }

    #[test]
    fn harmonic_rejects_overflow() {
        let exe = fake_exe(ExeKind::Harmonic);
        let rng = RngCtr { seed: [0, 0], base: 0, trial: 0 };
        let k: Vec<Vec<f64>> = (0..5).map(|_| vec![1.0]).collect();
        let a = vec![1.0; 5];
        assert!(harmonic_inputs(&exe, rng, 0, &k, &a, &a, &[0.0], &[1.0])
            .is_err());
    }

    #[test]
    fn vm_multi_null_padding() {
        let exe = fake_exe(ExeKind::VmMulti);
        let rng = RngCtr { seed: [0, 0], base: 0, trial: 0 };
        let f = VmFn {
            program: crate::expr::Expr::parse("x1*x2")
                .unwrap()
                .compile()
                .unwrap(),
            theta: vec![],
            bounds: vec![(0.0, 1.0), (0.0, 1.0)],
            stream: 42,
        };
        let prog_len = f.program.len() as i32;
        let vals = vm_multi_inputs(&exe, rng, &[f]).unwrap();
        match &vals[4] {
            Value::I32(ops) => {
                assert_eq!(ops.len(), 4 * MAX_PROG);
                // rows 1..4 are all HALT
                assert!(ops[MAX_PROG..].iter().all(|&o| o == 0));
            }
            _ => panic!(),
        }
        match &vals[2] {
            Value::U32(s) => assert_eq!(s, &vec![42, 0, 0, 0]),
            _ => panic!(),
        }
        match &vals[3] {
            // live slot carries its real length; null slots are 0
            Value::I32(p) => assert_eq!(p, &vec![prog_len, 0, 0, 0]),
            _ => panic!(),
        }
    }

    #[test]
    fn vm_multi_dim_mismatch_rejected() {
        let exe = fake_exe(ExeKind::VmMulti);
        let rng = RngCtr { seed: [0, 0], base: 0, trial: 0 };
        let f = VmFn {
            program: crate::expr::Expr::parse("x3").unwrap().compile().unwrap(),
            theta: vec![],
            bounds: vec![(0.0, 1.0)], // only 1 dim but program reads x3
            stream: 0,
        };
        assert!(vm_multi_inputs(&exe, rng, &[f]).is_err());
    }

    #[test]
    fn stratified_degenerate_padding() {
        let exe = fake_exe(ExeKind::Stratified);
        let rng = RngCtr { seed: [0, 0], base: 0, trial: 0 };
        let prog =
            crate::expr::Expr::parse("x1").unwrap().compile().unwrap();
        let cubes = vec![(vec![0.0], vec![0.5])];
        let vals =
            stratified_inputs(&exe, rng, &prog, &[], &cubes, &[7]).unwrap();
        match &vals[3] {
            Value::I32(p) => assert_eq!(p, &vec![prog.len() as i32]),
            _ => panic!(),
        }
        match &vals[9] {
            Value::F32(ch) => {
                assert_eq!(ch[0], 0.5);
                assert_eq!(ch[1], 1.0); // padded dim
                assert_eq!(ch[8], 0.0); // unused cube: degenerate
            }
            _ => panic!(),
        }
    }

    #[test]
    fn value_check() {
        let spec = TensorSpec {
            name: "x".into(),
            dtype: DType::F32,
            shape: vec![2, 3],
        };
        assert!(Value::F32(vec![0.0; 6]).check(&spec).is_ok());
        assert!(Value::F32(vec![0.0; 5]).check(&spec).is_err());
        assert!(Value::I32(vec![0; 6]).check(&spec).is_err());
    }
}
