//! PJRT runtime: load AOT artifacts and execute them from the rust
//! coordinator (no python anywhere on this path).
//!
//! * [`registry`] — parses `artifacts/manifest.json`, holds the HLO text
//!   of every executable plus its typed input/output signature. Shared
//!   (`Arc`) and thread-safe: it contains no PJRT objects.
//! * [`device`] — per-thread device handles. `PjRtClient` is `Rc`-based
//!   (not `Send`), so every worker thread owns a [`device::DeviceRuntime`]
//!   that lazily compiles executables from the shared registry; a
//!   [`device::DevicePool`] describes the simulated multi-GPU topology.
//! * [`launch`] — typed launch argument builders for the three artifact
//!   kinds (`harmonic`, `vm_multi`, `stratified`) and the dtype-checked
//!   literal conversion.

pub mod device;
pub mod launch;
pub mod registry;
