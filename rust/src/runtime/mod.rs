//! Device runtime: load AOT artifacts and execute them from the rust
//! coordinator (no python anywhere on this path).
//!
//! * [`registry`] — parses `artifacts/manifest.json`, holds the HLO text
//!   of every executable plus its typed input/output signature. Shared
//!   (`Arc`) and thread-safe: it contains no backend objects, and keeps
//!   the crate-wide compile ledger the warm-cache tests assert on.
//! * [`device`] — per-thread device handles. Backend clients are not
//!   `Send` (PJRT's is `Rc`-based), so every engine worker owns a
//!   [`device::DeviceRuntime`] that lazily compiles executables from the
//!   shared registry and keeps them cached for the worker's lifetime; a
//!   [`device::DevicePool`] describes the simulated multi-GPU topology.
//! * [`launch`] — typed launch argument builders for the three artifact
//!   kinds (`harmonic`, `vm_multi`, `stratified`) and the dtype-checked
//!   payload conversion.
//! * [`emulator`] — the default (no-`pjrt`) execution backend: a CPU
//!   interpreter bit-compatible with the kernels' Philox addressing and
//!   VM bytecode semantics, so the whole stack runs offline.

pub mod device;
#[cfg(not(feature = "pjrt"))]
pub mod emulator;
pub mod launch;
pub mod registry;

/// Which execution tier the emulator runs program launches through.
///
/// Three tiers share one contract — bit-identical `(Σf, Σf²)` moments —
/// and differ only in how much work they fuse per pass:
///
/// | tier    | sample gen            | evaluation        | reduction    |
/// |---------|-----------------------|-------------------|--------------|
/// | `Naive` | scalar `point()`      | stack interpreter | buffer fold  |
/// | `Plan`  | columnar `fill_columns` | `ExecPlan` columns | buffer fold |
/// | `Fused` | SIMD `fill_blocks`    | lane-block plan   | in-kernel    |
///
/// Selected per [`device::DevicePool`] (see the Session builder's
/// `execution_tier`), or process-wide via `ZMC_EMU_TIER=naive|plan|fused`.
/// The legacy `ZMC_EMU_NAIVE=1` switch still maps to `Naive` with a
/// one-time deprecation warning; `ZMC_EMU_TIER` supersedes it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecTier {
    /// Pre-plan stack interpreter — the bit-exact oracle path.
    Naive,
    /// Columnar [`crate::vm::ExecPlan`] pipeline over sample columns.
    Plan,
    /// Fused lane-batched pass ([`crate::vm::FusedPlan`]) — the default.
    #[default]
    Fused,
}

impl ExecTier {
    /// Parse a tier name (case-insensitive). `None` on unknown input.
    pub fn parse(s: &str) -> Option<ExecTier> {
        match s.trim().to_ascii_lowercase().as_str() {
            "naive" => Some(ExecTier::Naive),
            "plan" => Some(ExecTier::Plan),
            "fused" => Some(ExecTier::Fused),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ExecTier::Naive => "naive",
            ExecTier::Plan => "plan",
            ExecTier::Fused => "fused",
        }
    }

    /// Resolve the process-wide tier from the environment:
    /// `ZMC_EMU_TIER` wins, the deprecated `ZMC_EMU_NAIVE=1` maps to
    /// `Naive` (warning logged once), otherwise `Fused`.
    pub fn from_env() -> ExecTier {
        use std::sync::Once;
        if let Ok(v) = std::env::var("ZMC_EMU_TIER") {
            if let Some(t) = ExecTier::parse(&v) {
                return t;
            }
            static BAD: Once = Once::new();
            BAD.call_once(|| {
                eprintln!(
                    "warn: ZMC_EMU_TIER={v:?} not one of naive|plan|fused; \
                     using the default (fused)"
                );
            });
            return ExecTier::Fused;
        }
        if let Ok(v) = std::env::var("ZMC_EMU_NAIVE") {
            if v == "1" || v.eq_ignore_ascii_case("true") {
                static SHIM: Once = Once::new();
                SHIM.call_once(|| {
                    eprintln!(
                        "warn: ZMC_EMU_NAIVE is deprecated; \
                         use ZMC_EMU_TIER=naive"
                    );
                });
                return ExecTier::Naive;
            }
        }
        ExecTier::Fused
    }
}

impl std::fmt::Display for ExecTier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}
