//! Device runtime: load AOT artifacts and execute them from the rust
//! coordinator (no python anywhere on this path).
//!
//! * [`registry`] — parses `artifacts/manifest.json`, holds the HLO text
//!   of every executable plus its typed input/output signature. Shared
//!   (`Arc`) and thread-safe: it contains no backend objects, and keeps
//!   the crate-wide compile ledger the warm-cache tests assert on.
//! * [`device`] — per-thread device handles. Backend clients are not
//!   `Send` (PJRT's is `Rc`-based), so every engine worker owns a
//!   [`device::DeviceRuntime`] that lazily compiles executables from the
//!   shared registry and keeps them cached for the worker's lifetime; a
//!   [`device::DevicePool`] describes the simulated multi-GPU topology.
//! * [`launch`] — typed launch argument builders for the three artifact
//!   kinds (`harmonic`, `vm_multi`, `stratified`) and the dtype-checked
//!   payload conversion.
//! * [`emulator`] — the default (no-`pjrt`) execution backend: a CPU
//!   interpreter bit-compatible with the kernels' Philox addressing and
//!   VM bytecode semantics, so the whole stack runs offline.

pub mod device;
#[cfg(not(feature = "pjrt"))]
pub mod emulator;
pub mod launch;
pub mod registry;
