//! Artifact registry: manifest.json + HLO texts, validated at load time.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{anyhow, bail, Context, Result};

use crate::abi;
use crate::util::json::Json;

/// Element dtype of an artifact input/output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            "u32" => Ok(DType::U32),
            other => bail!("unsupported dtype '{other}' in manifest"),
        }
    }
}

/// One declared input/output tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Artifact kind — which launch-argument builder applies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExeKind {
    Harmonic,
    VmMulti,
    Stratified,
}

/// Metadata for one executable (one `.hlo.txt`).
#[derive(Debug, Clone)]
pub struct ExeSpec {
    pub name: String,
    pub kind: ExeKind,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Samples drawn per launch (per function for vm_multi/harmonic,
    /// per cube for stratified).
    pub samples: usize,
    /// Functions per launch (harmonic/vm_multi) — 0 for stratified.
    pub n_fns: usize,
    /// Cubes per launch (stratified) — 0 otherwise.
    pub n_cubes: usize,
    pub dims: usize,
    pub tile: usize,
    /// HLO text (compiled per worker thread on first use).
    pub hlo_text: String,
}

/// The loaded artifact set. `Send + Sync`; holds no PJRT state.
///
/// Also the crate-wide compile ledger: every [`DeviceRuntime`]
/// (`crate::runtime::device`) reports each executable compilation here,
/// so tests can assert the engine's warm-cache invariant — at most one
/// compile per worker per executable across arbitrarily many submits.
#[derive(Debug)]
pub struct Registry {
    pub dir: PathBuf,
    exes: BTreeMap<String, ExeSpec>,
    compiles: AtomicU64,
    /// Plan ledger (next to the compile ledger): program rows decoded +
    /// lowered to `ExecPlan`s across all workers, and plan-cache hits.
    plan_lowers: AtomicU64,
    plan_hits: AtomicU64,
    /// Fused ledger: program rows lowered to `FusedPlan`s across all
    /// workers, and fused-cache hits.
    fused_lowers: AtomicU64,
    fused_hits: AtomicU64,
    /// Batch-dedup ledger: canonical program classes the batch
    /// subsystem actually executed, and functions it folded into an
    /// existing class (programs the caches above never had to see).
    dedup_unique: AtomicU64,
    dedup_folded: AtomicU64,
}

impl Registry {
    /// Load and validate `dir/manifest.json` plus every HLO file it names.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Registry> {
        let dir = dir.as_ref().to_path_buf();
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading {}", mpath.display()))?;
        let manifest = Json::parse(&text)
            .map_err(|e| anyhow!("parsing {}: {e}", mpath.display()))?;

        let consts = manifest
            .get("constants")
            .context("manifest missing 'constants'")?;
        check_const(consts, "abi_version", abi::ABI_VERSION)?;
        check_const(consts, "MAX_DIM", abi::MAX_DIM as i64)?;
        check_const(consts, "MAX_PROG", abi::MAX_PROG as i64)?;
        check_const(consts, "STACK", abi::STACK as i64)?;
        check_const(consts, "MAX_PARAM", abi::MAX_PARAM as i64)?;

        let mut exes = BTreeMap::new();
        let table = manifest
            .get("executables")
            .and_then(Json::as_obj)
            .context("manifest missing 'executables'")?;
        for (name, entry) in table {
            let spec = parse_exe(&dir, name, entry)
                .with_context(|| format!("executable '{name}'"))?;
            exes.insert(name.clone(), spec);
        }
        if exes.is_empty() {
            bail!("manifest has no executables");
        }
        Ok(Registry {
            dir,
            exes,
            compiles: AtomicU64::new(0),
            plan_lowers: AtomicU64::new(0),
            plan_hits: AtomicU64::new(0),
            fused_lowers: AtomicU64::new(0),
            fused_hits: AtomicU64::new(0),
            dedup_unique: AtomicU64::new(0),
            dedup_folded: AtomicU64::new(0),
        })
    }

    /// Build a registry directly from specs (no manifest on disk) —
    /// used by the emulator registry and by tests.
    pub fn from_specs(
        dir: impl Into<PathBuf>,
        specs: Vec<ExeSpec>,
    ) -> Result<Registry> {
        if specs.is_empty() {
            bail!("registry needs at least one executable");
        }
        let mut exes = BTreeMap::new();
        for s in specs {
            exes.insert(s.name.clone(), s);
        }
        Ok(Registry {
            dir: dir.into(),
            exes,
            compiles: AtomicU64::new(0),
            plan_lowers: AtomicU64::new(0),
            plan_hits: AtomicU64::new(0),
            fused_lowers: AtomicU64::new(0),
            fused_hits: AtomicU64::new(0),
            dedup_unique: AtomicU64::new(0),
            dedup_folded: AtomicU64::new(0),
        })
    }

    /// The standard artifact set with synthetic HLO bodies, executable
    /// only by the in-process CPU emulator (the default, non-`pjrt`
    /// backend). Mirrors the names/shapes `make artifacts` produces so
    /// examples, the CLI and the test-suite run without python or PJRT.
    pub fn emulated() -> Registry {
        let specs = vec![
            vm_multi_spec("vm_multi_f8_s4096", 8, 4096, 8, 512),
            vm_multi_spec("vm_multi_f16_d4_s8192", 16, 8192, 4, 512),
            vm_multi_spec("vm_multi_f32_s16384", 32, 16384, 8, 1024),
            harmonic_spec("harmonic_s8192_n128", 128, 8192, 8, 512),
            harmonic_spec("harmonic_s65536_n128", 128, 65536, 8, 2048),
            stratified_spec("stratified_c16_s256", 16, 256, 8, 256),
            stratified_spec("stratified_c64_s1024", 64, 1024, 8, 512),
        ];
        Registry::from_specs("<emulated>", specs)
            .expect("emulated registry is non-empty")
    }

    /// FNV-1a/64 over the identity of every executable this registry
    /// holds — names, kinds, tensor specs, geometry, and the HLO text
    /// itself (the artifact content). Two hosts with the same digest
    /// will launch the same programs and produce bit-identical
    /// outputs; the cluster `Hello` handshake exchanges digests so a
    /// worker with drifted artifacts is rejected at connect time
    /// instead of silently diverging. `BTreeMap` iteration order
    /// makes the digest independent of load order.
    pub fn digest(&self) -> u64 {
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h = (h ^ u64::from(b)).wrapping_mul(PRIME);
            }
            // field separator so ("ab","c") != ("a","bc")
            h = (h ^ 0xff).wrapping_mul(PRIME);
        };
        for (name, s) in &self.exes {
            eat(name.as_bytes());
            eat(&[match s.kind {
                ExeKind::Harmonic => 0,
                ExeKind::VmMulti => 1,
                ExeKind::Stratified => 2,
            }]);
            for t in s.inputs.iter().chain(&s.outputs) {
                eat(t.name.as_bytes());
                eat(&[match t.dtype {
                    DType::F32 => 0,
                    DType::I32 => 1,
                    DType::U32 => 2,
                }]);
                for d in &t.shape {
                    eat(&(*d as u64).to_le_bytes());
                }
            }
            for v in [s.samples, s.n_fns, s.n_cubes, s.dims, s.tile] {
                eat(&(v as u64).to_le_bytes());
            }
            eat(s.hlo_text.as_bytes());
        }
        h
    }

    /// Count one executable compilation (called by device runtimes).
    pub fn note_compile(&self) {
        self.compiles.fetch_add(1, Ordering::Relaxed);
    }

    /// Total compilations across every worker since this registry was
    /// loaded. With a warm engine this saturates at
    /// `n_workers x distinct executables used`.
    pub fn compile_count(&self) -> u64 {
        self.compiles.load(Ordering::Relaxed)
    }

    /// Count one program row decoded + lowered to an `ExecPlan` (a
    /// plan-cache miss on some worker).
    pub fn note_plan_lower(&self) {
        self.plan_lowers.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one plan-cache hit.
    pub fn note_plan_hit(&self) {
        self.plan_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Program rows decoded + lowered across every worker. With warm
    /// plan caches this saturates at
    /// `n_workers x distinct program rows` — the same shape as
    /// [`Registry::compile_count`] for executables (asserted by
    /// `tests/engine_test.rs`).
    pub fn plan_lower_count(&self) -> u64 {
        self.plan_lowers.load(Ordering::Relaxed)
    }

    /// Plan-cache hits across every worker.
    pub fn plan_hit_count(&self) -> u64 {
        self.plan_hits.load(Ordering::Relaxed)
    }

    /// Count one program row lowered to a `FusedPlan` (a fused-cache
    /// miss on some worker).
    pub fn note_fused_lower(&self) {
        self.fused_lowers.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one fused-cache hit.
    pub fn note_fused_hit(&self) {
        self.fused_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Program rows lowered for the fused tier across every worker —
    /// the fused twin of [`Registry::plan_lower_count`], saturating at
    /// `n_workers x distinct program rows` under warm caches.
    pub fn fused_lower_count(&self) -> u64 {
        self.fused_lowers.load(Ordering::Relaxed)
    }

    /// Fused-cache hits across every worker.
    pub fn fused_hit_count(&self) -> u64 {
        self.fused_hits.load(Ordering::Relaxed)
    }

    /// Fold one batch run's dedup outcome into the ledger: `unique`
    /// canonical classes executed, `folded` functions that shared one
    /// (recorded by `crate::batch` per columnar run).
    pub fn note_dedup(&self, unique: u64, folded: u64) {
        if unique > 0 {
            self.dedup_unique.fetch_add(unique, Ordering::Relaxed);
        }
        if folded > 0 {
            self.dedup_folded.fetch_add(folded, Ordering::Relaxed);
        }
    }

    /// Canonical program classes executed via the batch dedup path
    /// since this registry was loaded — the dedup twin of
    /// [`Registry::plan_lower_count`]: with a parameter-scan batch this
    /// stays at the number of distinct program *shapes*, not functions.
    pub fn dedup_unique_count(&self) -> u64 {
        self.dedup_unique.load(Ordering::Relaxed)
    }

    /// Functions folded into an already-counted canonical class (their
    /// programs never reached the plan/fused caches or the compile
    /// ledger).
    pub fn dedup_folded_count(&self) -> u64 {
        self.dedup_folded.load(Ordering::Relaxed)
    }

    pub fn get(&self, name: &str) -> Result<&ExeSpec> {
        self.exes
            .get(name)
            .ok_or_else(|| anyhow!("no executable '{name}' in registry"))
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.exes.keys().map(String::as_str)
    }

    pub fn iter(&self) -> impl Iterator<Item = &ExeSpec> {
        self.exes.values()
    }

    /// Pick the executable of `kind` that fits the workload best:
    /// dims must cover `want_dims`; prefer the *smallest* covering dims
    /// (in-kernel RNG cost is one Philox block per 4 dims per sample),
    /// then the smallest per-launch capacity ≥ `want_samples` (else the
    /// largest available).
    pub fn pick(
        &self,
        kind: ExeKind,
        want_samples: usize,
        want_dims: usize,
    ) -> Result<&ExeSpec> {
        let mut best: Option<&ExeSpec> = None;
        for e in self
            .exes
            .values()
            .filter(|e| e.kind == kind && e.dims >= want_dims)
        {
            best = Some(match best {
                None => e,
                Some(cur) => {
                    if e.dims != cur.dims {
                        if e.dims < cur.dims { e } else { cur }
                    } else {
                        let fits = |x: &ExeSpec| x.samples >= want_samples;
                        match (fits(cur), fits(e)) {
                            (true, true) => {
                                if e.samples < cur.samples { e } else { cur }
                            }
                            (true, false) => cur,
                            (false, true) => e,
                            (false, false) => {
                                if e.samples > cur.samples { e } else { cur }
                            }
                        }
                    }
                }
            });
        }
        best.ok_or_else(|| {
            anyhow!("no executable of kind {kind:?} with dims >= {want_dims}")
        })
    }
}

// ---------------------------------------------------------------------
// Synthetic spec builders for the emulated registry. The input/output
// signatures must stay in lockstep with the builders in
// `crate::runtime::launch` (they are what `check_inputs` validates
// launches against).

fn tensor(name: &str, dtype: DType, shape: &[usize]) -> TensorSpec {
    TensorSpec { name: name.into(), dtype, shape: shape.to_vec() }
}

/// Synthetic `vm_multi` spec (emulator-executable; no HLO on disk).
/// Public so benches/tests can register custom geometries — e.g. the
/// small-sample, wide-function shapes the batch-throughput bench uses —
/// via [`Registry::from_specs`] without hand-writing the tensor
/// signature that `check_inputs` validates against.
pub fn vm_multi_spec(
    name: &str,
    n_fns: usize,
    samples: usize,
    dims: usize,
    tile: usize,
) -> ExeSpec {
    let p = abi::MAX_PROG;
    ExeSpec {
        name: name.to_string(),
        kind: ExeKind::VmMulti,
        inputs: vec![
            tensor("seed", DType::U32, &[2]),
            tensor("ctr", DType::U32, &[2]),
            tensor("streams", DType::U32, &[n_fns]),
            tensor("plens", DType::I32, &[n_fns]),
            tensor("ops", DType::I32, &[n_fns, p]),
            tensor("iargs", DType::I32, &[n_fns, p]),
            tensor("fargs", DType::F32, &[n_fns, p]),
            tensor("theta", DType::F32, &[n_fns, abi::MAX_PARAM]),
            tensor("lo", DType::F32, &[n_fns, dims]),
            tensor("hi", DType::F32, &[n_fns, dims]),
        ],
        outputs: vec![tensor("moments", DType::F32, &[n_fns, 2])],
        samples,
        n_fns,
        n_cubes: 0,
        dims,
        tile,
        hlo_text: format!("HloModule emulated_{name}\n"),
    }
}

fn harmonic_spec(
    name: &str,
    n_fns: usize,
    samples: usize,
    dims: usize,
    tile: usize,
) -> ExeSpec {
    ExeSpec {
        name: name.to_string(),
        kind: ExeKind::Harmonic,
        inputs: vec![
            tensor("seed", DType::U32, &[2]),
            tensor("ctr", DType::U32, &[3]),
            tensor("k", DType::F32, &[n_fns, dims]),
            tensor("a", DType::F32, &[n_fns]),
            tensor("b", DType::F32, &[n_fns]),
            tensor("lo", DType::F32, &[dims]),
            tensor("hi", DType::F32, &[dims]),
        ],
        outputs: vec![tensor("moments", DType::F32, &[2, n_fns])],
        samples,
        n_fns,
        n_cubes: 0,
        dims,
        tile,
        hlo_text: format!("HloModule emulated_{name}\n"),
    }
}

fn stratified_spec(
    name: &str,
    n_cubes: usize,
    samples: usize,
    dims: usize,
    tile: usize,
) -> ExeSpec {
    let p = abi::MAX_PROG;
    ExeSpec {
        name: name.to_string(),
        kind: ExeKind::Stratified,
        inputs: vec![
            tensor("seed", DType::U32, &[2]),
            tensor("ctr", DType::U32, &[2]),
            tensor("streams", DType::U32, &[n_cubes]),
            tensor("plen", DType::I32, &[1]),
            tensor("ops", DType::I32, &[p]),
            tensor("iargs", DType::I32, &[p]),
            tensor("fargs", DType::F32, &[p]),
            tensor("theta", DType::F32, &[abi::MAX_PARAM]),
            tensor("cl", DType::F32, &[n_cubes, dims]),
            tensor("ch", DType::F32, &[n_cubes, dims]),
        ],
        outputs: vec![tensor("moments", DType::F32, &[n_cubes, 2])],
        samples,
        n_fns: 0,
        n_cubes,
        dims,
        tile,
        hlo_text: format!("HloModule emulated_{name}\n"),
    }
}

fn check_const(consts: &Json, key: &str, want: i64) -> Result<()> {
    let got = consts
        .get(key)
        .and_then(Json::as_i64)
        .with_context(|| format!("manifest constants missing '{key}'"))?;
    if got != want {
        bail!(
            "ABI mismatch: manifest {key}={got}, this build expects {want} \
             — re-run `make artifacts`"
        );
    }
    Ok(())
}

fn parse_tensor(j: &Json) -> Result<TensorSpec> {
    let name = j
        .get("name")
        .and_then(Json::as_str)
        .unwrap_or("out")
        .to_string();
    let dtype = DType::parse(
        j.get("dtype")
            .and_then(Json::as_str)
            .context("tensor missing dtype")?,
    )?;
    let shape = j
        .get("shape")
        .and_then(Json::as_arr)
        .context("tensor missing shape")?
        .iter()
        .map(|d| d.as_usize().context("bad shape dim"))
        .collect::<Result<Vec<_>>>()?;
    Ok(TensorSpec { name, dtype, shape })
}

fn parse_exe(dir: &Path, name: &str, entry: &Json) -> Result<ExeSpec> {
    let kind = match entry
        .get("kind")
        .and_then(Json::as_str)
        .context("missing kind")?
    {
        "harmonic" => ExeKind::Harmonic,
        "vm_multi" => ExeKind::VmMulti,
        "stratified" => ExeKind::Stratified,
        other => bail!("unknown executable kind '{other}'"),
    };
    let get_n = |key: &str| -> usize {
        entry.get(key).and_then(Json::as_usize).unwrap_or(0)
    };
    let file = entry
        .get("file")
        .and_then(Json::as_str)
        .context("missing file")?;
    let hlo_path = dir.join(file);
    let hlo_text = std::fs::read_to_string(&hlo_path)
        .with_context(|| format!("reading {}", hlo_path.display()))?;
    if !hlo_text.contains("HloModule") {
        bail!("{} does not look like HLO text", hlo_path.display());
    }
    let inputs = entry
        .get("inputs")
        .and_then(Json::as_arr)
        .context("missing inputs")?
        .iter()
        .map(parse_tensor)
        .collect::<Result<Vec<_>>>()?;
    let outputs = entry
        .get("outputs")
        .and_then(Json::as_arr)
        .context("missing outputs")?
        .iter()
        .map(parse_tensor)
        .collect::<Result<Vec<_>>>()?;
    let spec = ExeSpec {
        name: name.to_string(),
        kind,
        inputs,
        outputs,
        samples: get_n("samples"),
        n_fns: get_n("n_fns"),
        n_cubes: get_n("n_cubes"),
        dims: get_n("dims"),
        tile: get_n("tile"),
        hlo_text,
    };
    if spec.samples == 0 || spec.dims == 0 {
        bail!("missing samples/dims metadata");
    }
    Ok(spec)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_shipped_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let reg = Registry::load(artifacts_dir()).unwrap();
        assert!(reg.names().count() >= 6);
        let h = reg.get("harmonic_s65536_n128").unwrap();
        assert_eq!(h.kind, ExeKind::Harmonic);
        assert_eq!(h.samples, 65536);
        assert_eq!(h.n_fns, 128);
        assert_eq!(h.inputs.len(), 7);
        assert_eq!(h.outputs[0].shape, vec![2, 128]);
        assert!(h.hlo_text.contains("HloModule"));
    }

    #[test]
    fn pick_prefers_smallest_fitting() {
        if !have_artifacts() {
            return;
        }
        let reg = Registry::load(artifacts_dir()).unwrap();
        let small = reg.pick(ExeKind::Harmonic, 1000, 4).unwrap();
        assert_eq!(small.samples, 8192);
        let big = reg.pick(ExeKind::Harmonic, 50_000, 4).unwrap();
        assert_eq!(big.samples, 65536);
        let over = reg.pick(ExeKind::Harmonic, 10_000_000, 4).unwrap();
        assert_eq!(over.samples, 65536);
    }

    #[test]
    fn pick_is_dims_aware() {
        if !have_artifacts() {
            return;
        }
        let reg = Registry::load(artifacts_dir()).unwrap();
        // dims<=4 jobs get the cheaper d4 artifact
        let d4 = reg.pick(ExeKind::VmMulti, 16384, 3).unwrap();
        assert_eq!(d4.dims, 4, "{}", d4.name);
        // dims>4 jobs fall back to the d8 artifact
        let d8 = reg.pick(ExeKind::VmMulti, 16384, 6).unwrap();
        assert_eq!(d8.dims, 8, "{}", d8.name);
        // impossible dims requirement errors
        assert!(reg.pick(ExeKind::VmMulti, 16384, 9).is_err());
    }

    #[test]
    fn missing_dir_errors() {
        assert!(Registry::load("/nonexistent/path").is_err());
    }

    #[test]
    fn emulated_registry_matches_launch_builders() {
        let reg = Registry::emulated();
        assert!(reg.names().count() >= 6);
        let vm = reg.get("vm_multi_f8_s4096").unwrap();
        assert_eq!(vm.kind, ExeKind::VmMulti);
        assert_eq!(vm.inputs.len(), 10);
        assert_eq!(vm.outputs[0].shape, vec![8, 2]);
        let h = reg.get("harmonic_s8192_n128").unwrap();
        assert_eq!(h.inputs.len(), 7);
        assert_eq!(h.outputs[0].shape, vec![2, 128]);
        let s = reg.get("stratified_c16_s256").unwrap();
        assert_eq!(s.n_cubes, 16);
        // dims-aware pick prefers the d4 artifact for low-dim batches
        let d4 = reg.pick(ExeKind::VmMulti, 8192, 3).unwrap();
        assert_eq!(d4.dims, 4);
        let d8 = reg.pick(ExeKind::VmMulti, 8192, 6).unwrap();
        assert_eq!(d8.dims, 8);
    }

    #[test]
    fn compile_counter_accumulates() {
        let reg = Registry::emulated();
        assert_eq!(reg.compile_count(), 0);
        reg.note_compile();
        reg.note_compile();
        assert_eq!(reg.compile_count(), 2);
    }

    #[test]
    fn plan_ledger_accumulates() {
        let reg = Registry::emulated();
        assert_eq!(reg.plan_lower_count(), 0);
        assert_eq!(reg.plan_hit_count(), 0);
        reg.note_plan_lower();
        reg.note_plan_hit();
        reg.note_plan_hit();
        assert_eq!(reg.plan_lower_count(), 1);
        assert_eq!(reg.plan_hit_count(), 2);
    }

    #[test]
    fn digest_is_stable_and_content_sensitive() {
        let a = Registry::emulated().digest();
        let b = Registry::emulated().digest();
        assert_eq!(a, b, "same specs, same digest");
        assert_ne!(a, 0, "0 is the 'unchecked' sentinel on the wire");
        // one byte of HLO drift must change the digest
        let mut specs: Vec<ExeSpec> =
            Registry::emulated().iter().cloned().collect();
        specs[0].hlo_text.push('x');
        let drifted =
            Registry::from_specs("<emulated>", specs).unwrap().digest();
        assert_ne!(a, drifted, "artifact drift must change the digest");
    }

    #[test]
    fn abi_mismatch_detected() {
        let dir = std::env::temp_dir().join(format!(
            "zmc_reg_test_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"constants":{"abi_version":99,"MAX_DIM":8,"MAX_PROG":48,
                "STACK":16,"MAX_PARAM":16},"executables":{}}"#,
        )
        .unwrap();
        let err = Registry::load(&dir).unwrap_err().to_string();
        assert!(err.contains("ABI mismatch"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
