//! Artifact registry: manifest.json + HLO texts, validated at load time.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::abi;
use crate::util::json::Json;

/// Element dtype of an artifact input/output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            "u32" => Ok(DType::U32),
            other => bail!("unsupported dtype '{other}' in manifest"),
        }
    }
}

/// One declared input/output tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Artifact kind — which launch-argument builder applies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExeKind {
    Harmonic,
    VmMulti,
    Stratified,
}

/// Metadata for one executable (one `.hlo.txt`).
#[derive(Debug, Clone)]
pub struct ExeSpec {
    pub name: String,
    pub kind: ExeKind,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Samples drawn per launch (per function for vm_multi/harmonic,
    /// per cube for stratified).
    pub samples: usize,
    /// Functions per launch (harmonic/vm_multi) — 0 for stratified.
    pub n_fns: usize,
    /// Cubes per launch (stratified) — 0 otherwise.
    pub n_cubes: usize,
    pub dims: usize,
    pub tile: usize,
    /// HLO text (compiled per worker thread on first use).
    pub hlo_text: String,
}

/// The loaded artifact set. `Send + Sync`; holds no PJRT state.
#[derive(Debug)]
pub struct Registry {
    pub dir: PathBuf,
    exes: BTreeMap<String, ExeSpec>,
}

impl Registry {
    /// Load and validate `dir/manifest.json` plus every HLO file it names.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Registry> {
        let dir = dir.as_ref().to_path_buf();
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading {}", mpath.display()))?;
        let manifest = Json::parse(&text)
            .map_err(|e| anyhow!("parsing {}: {e}", mpath.display()))?;

        let consts = manifest
            .get("constants")
            .context("manifest missing 'constants'")?;
        check_const(consts, "abi_version", abi::ABI_VERSION)?;
        check_const(consts, "MAX_DIM", abi::MAX_DIM as i64)?;
        check_const(consts, "MAX_PROG", abi::MAX_PROG as i64)?;
        check_const(consts, "STACK", abi::STACK as i64)?;
        check_const(consts, "MAX_PARAM", abi::MAX_PARAM as i64)?;

        let mut exes = BTreeMap::new();
        let table = manifest
            .get("executables")
            .and_then(Json::as_obj)
            .context("manifest missing 'executables'")?;
        for (name, entry) in table {
            let spec = parse_exe(&dir, name, entry)
                .with_context(|| format!("executable '{name}'"))?;
            exes.insert(name.clone(), spec);
        }
        if exes.is_empty() {
            bail!("manifest has no executables");
        }
        Ok(Registry { dir, exes })
    }

    pub fn get(&self, name: &str) -> Result<&ExeSpec> {
        self.exes
            .get(name)
            .ok_or_else(|| anyhow!("no executable '{name}' in registry"))
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.exes.keys().map(String::as_str)
    }

    pub fn iter(&self) -> impl Iterator<Item = &ExeSpec> {
        self.exes.values()
    }

    /// Pick the executable of `kind` that fits the workload best:
    /// dims must cover `want_dims`; prefer the *smallest* covering dims
    /// (in-kernel RNG cost is one Philox block per 4 dims per sample),
    /// then the smallest per-launch capacity ≥ `want_samples` (else the
    /// largest available).
    pub fn pick(
        &self,
        kind: ExeKind,
        want_samples: usize,
        want_dims: usize,
    ) -> Result<&ExeSpec> {
        let mut best: Option<&ExeSpec> = None;
        for e in self
            .exes
            .values()
            .filter(|e| e.kind == kind && e.dims >= want_dims)
        {
            best = Some(match best {
                None => e,
                Some(cur) => {
                    if e.dims != cur.dims {
                        if e.dims < cur.dims { e } else { cur }
                    } else {
                        let fits = |x: &ExeSpec| x.samples >= want_samples;
                        match (fits(cur), fits(e)) {
                            (true, true) => {
                                if e.samples < cur.samples { e } else { cur }
                            }
                            (true, false) => cur,
                            (false, true) => e,
                            (false, false) => {
                                if e.samples > cur.samples { e } else { cur }
                            }
                        }
                    }
                }
            });
        }
        best.ok_or_else(|| {
            anyhow!("no executable of kind {kind:?} with dims >= {want_dims}")
        })
    }
}

fn check_const(consts: &Json, key: &str, want: i64) -> Result<()> {
    let got = consts
        .get(key)
        .and_then(Json::as_i64)
        .with_context(|| format!("manifest constants missing '{key}'"))?;
    if got != want {
        bail!(
            "ABI mismatch: manifest {key}={got}, this build expects {want} \
             — re-run `make artifacts`"
        );
    }
    Ok(())
}

fn parse_tensor(j: &Json) -> Result<TensorSpec> {
    let name = j
        .get("name")
        .and_then(Json::as_str)
        .unwrap_or("out")
        .to_string();
    let dtype = DType::parse(
        j.get("dtype")
            .and_then(Json::as_str)
            .context("tensor missing dtype")?,
    )?;
    let shape = j
        .get("shape")
        .and_then(Json::as_arr)
        .context("tensor missing shape")?
        .iter()
        .map(|d| d.as_usize().context("bad shape dim"))
        .collect::<Result<Vec<_>>>()?;
    Ok(TensorSpec { name, dtype, shape })
}

fn parse_exe(dir: &Path, name: &str, entry: &Json) -> Result<ExeSpec> {
    let kind = match entry
        .get("kind")
        .and_then(Json::as_str)
        .context("missing kind")?
    {
        "harmonic" => ExeKind::Harmonic,
        "vm_multi" => ExeKind::VmMulti,
        "stratified" => ExeKind::Stratified,
        other => bail!("unknown executable kind '{other}'"),
    };
    let get_n = |key: &str| -> usize {
        entry.get(key).and_then(Json::as_usize).unwrap_or(0)
    };
    let file = entry
        .get("file")
        .and_then(Json::as_str)
        .context("missing file")?;
    let hlo_path = dir.join(file);
    let hlo_text = std::fs::read_to_string(&hlo_path)
        .with_context(|| format!("reading {}", hlo_path.display()))?;
    if !hlo_text.contains("HloModule") {
        bail!("{} does not look like HLO text", hlo_path.display());
    }
    let inputs = entry
        .get("inputs")
        .and_then(Json::as_arr)
        .context("missing inputs")?
        .iter()
        .map(parse_tensor)
        .collect::<Result<Vec<_>>>()?;
    let outputs = entry
        .get("outputs")
        .and_then(Json::as_arr)
        .context("missing outputs")?
        .iter()
        .map(parse_tensor)
        .collect::<Result<Vec<_>>>()?;
    let spec = ExeSpec {
        name: name.to_string(),
        kind,
        inputs,
        outputs,
        samples: get_n("samples"),
        n_fns: get_n("n_fns"),
        n_cubes: get_n("n_cubes"),
        dims: get_n("dims"),
        tile: get_n("tile"),
        hlo_text,
    };
    if spec.samples == 0 || spec.dims == 0 {
        bail!("missing samples/dims metadata");
    }
    Ok(spec)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_shipped_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let reg = Registry::load(artifacts_dir()).unwrap();
        assert!(reg.names().count() >= 6);
        let h = reg.get("harmonic_s65536_n128").unwrap();
        assert_eq!(h.kind, ExeKind::Harmonic);
        assert_eq!(h.samples, 65536);
        assert_eq!(h.n_fns, 128);
        assert_eq!(h.inputs.len(), 7);
        assert_eq!(h.outputs[0].shape, vec![2, 128]);
        assert!(h.hlo_text.contains("HloModule"));
    }

    #[test]
    fn pick_prefers_smallest_fitting() {
        if !have_artifacts() {
            return;
        }
        let reg = Registry::load(artifacts_dir()).unwrap();
        let small = reg.pick(ExeKind::Harmonic, 1000, 4).unwrap();
        assert_eq!(small.samples, 8192);
        let big = reg.pick(ExeKind::Harmonic, 50_000, 4).unwrap();
        assert_eq!(big.samples, 65536);
        let over = reg.pick(ExeKind::Harmonic, 10_000_000, 4).unwrap();
        assert_eq!(over.samples, 65536);
    }

    #[test]
    fn pick_is_dims_aware() {
        if !have_artifacts() {
            return;
        }
        let reg = Registry::load(artifacts_dir()).unwrap();
        // dims<=4 jobs get the cheaper d4 artifact
        let d4 = reg.pick(ExeKind::VmMulti, 16384, 3).unwrap();
        assert_eq!(d4.dims, 4, "{}", d4.name);
        // dims>4 jobs fall back to the d8 artifact
        let d8 = reg.pick(ExeKind::VmMulti, 16384, 6).unwrap();
        assert_eq!(d8.dims, 8, "{}", d8.name);
        // impossible dims requirement errors
        assert!(reg.pick(ExeKind::VmMulti, 16384, 9).is_err());
    }

    #[test]
    fn missing_dir_errors() {
        assert!(Registry::load("/nonexistent/path").is_err());
    }

    #[test]
    fn abi_mismatch_detected() {
        let dir = std::env::temp_dir().join(format!(
            "zmc_reg_test_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"constants":{"abi_version":99,"MAX_DIM":8,"MAX_PROG":48,
                "STACK":16,"MAX_PARAM":16},"executables":{}}"#,
        )
        .unwrap();
        let err = Registry::load(&dir).unwrap_err().to_string();
        assert!(err.contains("ABI mismatch"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
