//! Per-thread device handles and the simulated multi-GPU pool.
//!
//! The paper runs on N Tesla V100s coordinated by Ray; our testbed has
//! no GPU. A "device" here is an engine worker thread owning its own
//! [`DeviceRuntime`] with a lazily-populated executable cache compiled
//! from the shared [`Registry`] HLO texts. Two backends sit behind the
//! same `DeviceRuntime` API:
//!
//! * `--features pjrt` — the real PJRT CPU plugin via the `xla`
//!   bindings (the crate's client is `Rc`-based and must not cross
//!   threads, hence one client per worker);
//! * default — the in-process CPU emulator
//!   ([`crate::runtime::emulator`]), bit-compatible with the kernels'
//!   Philox streams and VM semantics.
//!
//! Either way the scheduling/batching/caching logic above is identical
//! to what a real multi-accelerator deployment would use; see DESIGN.md
//! "Substitutions" for the fidelity argument.

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

#[cfg(not(feature = "pjrt"))]
use crate::runtime::emulator::{EmuExe, EmuState};
use crate::runtime::launch::Value;
#[cfg(feature = "pjrt")]
use crate::runtime::registry::TensorSpec;
use crate::runtime::registry::{ExeSpec, Registry};
use crate::runtime::ExecTier;

/// Output of one device launch: flat f32 payload + wall time on device.
#[derive(Debug, Clone)]
pub struct LaunchOutput {
    pub data: Vec<f32>,
    pub device_time: Duration,
}

#[cfg(feature = "pjrt")]
type CompiledExe = xla::PjRtLoadedExecutable;
#[cfg(not(feature = "pjrt"))]
type CompiledExe = EmuExe;

/// One-time process init for the PJRT plugin's logging default.
///
/// `std::env::set_var` is unsound when racing other threads reading the
/// environment, and engine workers are spawned concurrently — so the
/// default is installed exactly once behind a `Once` instead of from
/// every worker's constructor.
#[cfg(feature = "pjrt")]
fn init_tf_logging_once() {
    use std::sync::Once;
    static TF_LOG: Once = Once::new();
    TF_LOG.call_once(|| {
        // silence TfrtCpuClient created/destroyed info chatter unless
        // the user already configured TF logging
        if std::env::var_os("TF_CPP_MIN_LOG_LEVEL").is_none() {
            std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
        }
    });
}

/// One simulated accelerator: per-worker backend client + exe cache.
///
/// The cache is the engine's warm state: under the persistent engine a
/// `DeviceRuntime` lives as long as its worker thread, so each
/// executable is compiled at most once per worker for the process
/// lifetime (counted in [`Registry::compile_count`]).
pub struct DeviceRuntime {
    registry: Arc<Registry>,
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, CompiledExe>>,
    /// Per-worker emulator state: scratch arenas + the `ExecPlan` LRU
    /// cache, both living as long as this runtime (the engine's warm
    /// state for *programs*, next to the executable cache above).
    #[cfg(not(feature = "pjrt"))]
    emu: RefCell<EmuState>,
    /// Cumulative time spent executing (for utilization metrics).
    busy: RefCell<Duration>,
}

impl DeviceRuntime {
    #[cfg(feature = "pjrt")]
    pub fn new(registry: Arc<Registry>) -> Result<Self> {
        init_tf_logging_once();
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
        Ok(DeviceRuntime {
            registry,
            client,
            cache: RefCell::new(HashMap::new()),
            busy: RefCell::new(Duration::ZERO),
        })
    }

    /// Runtime with the process-wide emulator tier
    /// ([`ExecTier::from_env`]); under PJRT the tier is moot (programs
    /// are lowered on device).
    #[cfg(not(feature = "pjrt"))]
    pub fn new(registry: Arc<Registry>) -> Result<Self> {
        DeviceRuntime::with_tier(registry, ExecTier::from_env())
    }

    /// Runtime pinned to an emulator execution tier (the device-pool /
    /// Session plumbing lands here).
    #[cfg(not(feature = "pjrt"))]
    pub fn with_tier(registry: Arc<Registry>, tier: ExecTier) -> Result<Self> {
        Ok(DeviceRuntime {
            registry,
            cache: RefCell::new(HashMap::new()),
            emu: RefCell::new(EmuState::with_tier(tier)),
            busy: RefCell::new(Duration::ZERO),
        })
    }

    /// Runtime with the pool's tier override, or the process-wide tier
    /// when the pool doesn't pin one. (PJRT builds ignore the tier.)
    pub fn for_pool(pool: &DevicePool) -> Result<Self> {
        #[cfg(not(feature = "pjrt"))]
        {
            match pool.tier {
                Some(t) => {
                    DeviceRuntime::with_tier(Arc::clone(&pool.registry), t)
                }
                None => DeviceRuntime::new(Arc::clone(&pool.registry)),
            }
        }
        #[cfg(feature = "pjrt")]
        {
            DeviceRuntime::new(Arc::clone(&pool.registry))
        }
    }

    /// The emulator execution tier this runtime's launches run through
    /// (`None` on the PJRT backend).
    pub fn tier(&self) -> Option<ExecTier> {
        #[cfg(not(feature = "pjrt"))]
        {
            Some(self.emu.borrow().tier())
        }
        #[cfg(feature = "pjrt")]
        {
            None
        }
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn busy_time(&self) -> Duration {
        *self.busy.borrow()
    }

    /// Executables compiled by *this* runtime so far.
    pub fn cached_executables(&self) -> usize {
        self.cache.borrow().len()
    }

    /// `ExecPlan`s currently cached by this runtime's plan LRU (always
    /// 0 on the PJRT backend, where programs are lowered on device).
    pub fn cached_plans(&self) -> usize {
        #[cfg(not(feature = "pjrt"))]
        {
            self.emu.borrow().cached_plans()
        }
        #[cfg(feature = "pjrt")]
        {
            0
        }
    }

    /// Drain plan-cache (hits, misses) since the last call — the engine
    /// backend folds these into its run metrics after each task.
    pub fn take_plan_events(&self) -> (u64, u64) {
        #[cfg(not(feature = "pjrt"))]
        {
            self.emu.borrow_mut().take_plan_events()
        }
        #[cfg(feature = "pjrt")]
        {
            (0, 0)
        }
    }

    /// Drain fused-cache (hits, misses) since the last call — the
    /// fused-tier twin of [`DeviceRuntime::take_plan_events`].
    pub fn take_fused_events(&self) -> (u64, u64) {
        #[cfg(not(feature = "pjrt"))]
        {
            self.emu.borrow_mut().take_fused_events()
        }
        #[cfg(feature = "pjrt")]
        {
            (0, 0)
        }
    }

    /// Compile (or fetch cached) and execute `exe_name` with `inputs`.
    pub fn execute(&self, exe_name: &str, inputs: &[Value]) -> Result<LaunchOutput> {
        let spec = self.registry.get(exe_name)?;
        self.check_inputs(spec, inputs)?;
        self.ensure_compiled(spec)?;
        let t0 = Instant::now();
        let data = self.run_compiled(spec, inputs)?;
        let dt = t0.elapsed();
        *self.busy.borrow_mut() += dt;

        let want: usize = spec.outputs[0].elements();
        if data.len() != want {
            return Err(anyhow!(
                "{exe_name}: output has {} elements, manifest says {want}",
                data.len()
            ));
        }
        Ok(LaunchOutput { data, device_time: dt })
    }

    #[cfg(feature = "pjrt")]
    fn run_compiled(&self, spec: &ExeSpec, inputs: &[Value]) -> Result<Vec<f32>> {
        let cache = self.cache.borrow();
        let exe = cache.get(&spec.name).expect("just compiled");
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .zip(&spec.inputs)
            .map(|(v, ts)| literal_for_spec(ts, v))
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {}: {e:?}", spec.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch output: {e:?}"))?;
        // Artifacts are lowered with return_tuple=True -> unwrap 1-tuple.
        let out = lit
            .to_tuple1()
            .map_err(|e| anyhow!("output not a 1-tuple: {e:?}"))?;
        out.to_vec::<f32>()
            .map_err(|e| anyhow!("output to_vec: {e:?}"))
    }

    #[cfg(not(feature = "pjrt"))]
    fn run_compiled(&self, spec: &ExeSpec, inputs: &[Value]) -> Result<Vec<f32>> {
        let cache = self.cache.borrow();
        let exe = cache.get(&spec.name).expect("just compiled");
        exe.execute(spec, inputs, &mut self.emu.borrow_mut(), &self.registry)
    }

    fn check_inputs(&self, spec: &ExeSpec, inputs: &[Value]) -> Result<()> {
        if inputs.len() != spec.inputs.len() {
            return Err(anyhow!(
                "{}: {} inputs given, manifest wants {}",
                spec.name,
                inputs.len(),
                spec.inputs.len()
            ));
        }
        for (v, ts) in inputs.iter().zip(&spec.inputs) {
            v.check(ts).with_context(|| spec.name.clone())?;
        }
        Ok(())
    }

    fn ensure_compiled(&self, spec: &ExeSpec) -> Result<()> {
        if self.cache.borrow().contains_key(&spec.name) {
            return Ok(());
        }
        let exe = self.compile(spec)?;
        self.registry.note_compile();
        self.cache.borrow_mut().insert(spec.name.clone(), exe);
        Ok(())
    }

    #[cfg(feature = "pjrt")]
    fn compile(&self, spec: &ExeSpec) -> Result<CompiledExe> {
        let proto = xla::HloModuleProto::parse_and_return_unverified_module(
            spec.hlo_text.as_bytes(),
        )
        .map_err(|e| anyhow!("parse HLO {}: {e:?}", spec.name))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", spec.name))
    }

    #[cfg(not(feature = "pjrt"))]
    fn compile(&self, spec: &ExeSpec) -> Result<CompiledExe> {
        EmuExe::compile(spec)
    }

    /// Pre-compile a set of executables (worker warmup).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.ensure_compiled(self.registry.get(n)?)?;
        }
        Ok(())
    }
}

#[cfg(feature = "pjrt")]
fn value_to_literal(v: &Value) -> Result<xla::Literal> {
    Ok(match v {
        Value::F32(x) => xla::Literal::vec1(x),
        Value::I32(x) => xla::Literal::vec1(x),
        Value::U32(x) => xla::Literal::vec1(x),
    })
}

/// Build a literal with the exact ranked shape the manifest declares
/// (the lowered HLO has ranked parameters, e.g. `f32[128,8]`).
#[cfg(feature = "pjrt")]
fn literal_for_spec(ts: &TensorSpec, v: &Value) -> Result<xla::Literal> {
    let flat = value_to_literal(v)?;
    if ts.shape.len() <= 1 {
        return Ok(flat);
    }
    let dims: Vec<i64> = ts.shape.iter().map(|&d| d as i64).collect();
    flat.reshape(&dims)
        .map_err(|e| anyhow!("reshape input '{}': {e:?}", ts.name))
}

/// Topology descriptor for the simulated cluster: how many device
/// workers the engine should spawn. (Each worker builds its own
/// [`DeviceRuntime`] on its own thread and keeps it for the engine's
/// lifetime.)
#[derive(Debug, Clone)]
pub struct DevicePool {
    pub registry: Arc<Registry>,
    pub n_devices: usize,
    /// Emulator execution tier every worker in this pool pins its
    /// [`DeviceRuntime`] to; `None` defers to [`ExecTier::from_env`].
    pub tier: Option<ExecTier>,
}

impl DevicePool {
    pub fn new(registry: &Arc<Registry>, n_devices: usize) -> Result<Self> {
        if n_devices == 0 {
            return Err(anyhow!("device pool needs >= 1 device"));
        }
        Ok(DevicePool {
            registry: Arc::clone(registry),
            n_devices,
            tier: None,
        })
    }

    /// Pin every worker of this pool to one emulator execution tier.
    pub fn with_tier(mut self, tier: ExecTier) -> Self {
        self.tier = Some(tier);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::registry::DType as D;
    use crate::runtime::registry::TensorSpec;

    #[test]
    fn pool_rejects_zero_devices() {
        let reg = Arc::new(Registry::emulated());
        assert!(DevicePool::new(&reg, 0).is_err());
        assert_eq!(DevicePool::new(&reg, 4).unwrap().n_devices, 4);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pool_tier_pins_runtimes() {
        let reg = Arc::new(Registry::emulated());
        let pool =
            DevicePool::new(&reg, 2).unwrap().with_tier(ExecTier::Plan);
        assert_eq!(pool.tier, Some(ExecTier::Plan));
        let dev = DeviceRuntime::for_pool(&pool).unwrap();
        assert_eq!(dev.tier(), Some(ExecTier::Plan));
        // unpinned pools defer to the process-wide default
        let pool = DevicePool::new(&reg, 1).unwrap();
        let dev = DeviceRuntime::for_pool(&pool).unwrap();
        assert_eq!(dev.tier(), Some(ExecTier::from_env()));
    }

    #[test]
    fn tensor_spec_elements() {
        let ts = TensorSpec { name: "k".into(), dtype: D::F32, shape: vec![4, 8] };
        assert_eq!(ts.elements(), 32);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn emulated_runtime_compiles_once_per_exe() {
        use crate::expr::Expr;
        use crate::runtime::launch::{vm_multi_inputs, RngCtr, VmFn};

        let reg = Arc::new(Registry::emulated());
        let dev = DeviceRuntime::new(Arc::clone(&reg)).unwrap();
        let exe = reg.get("vm_multi_f8_s4096").unwrap();
        let f = VmFn {
            program: Expr::parse("x1").unwrap().compile().unwrap(),
            theta: vec![],
            bounds: vec![(0.0, 1.0)],
            stream: 0,
        };
        let rng = RngCtr { seed: [1, 1], base: 0, trial: 0 };
        let inputs =
            vm_multi_inputs(exe, rng, std::slice::from_ref(&f)).unwrap();
        let a = dev.execute(&exe.name, &inputs).unwrap();
        let b = dev.execute(&exe.name, &inputs).unwrap();
        assert_eq!(a.data, b.data); // idempotent launches
        assert_eq!(reg.compile_count(), 1); // second call hit the cache
        assert_eq!(dev.cached_executables(), 1);
        assert!(dev.busy_time() > Duration::ZERO);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn execute_rejects_malformed_inputs() {
        let reg = Arc::new(Registry::emulated());
        let dev = DeviceRuntime::new(Arc::clone(&reg)).unwrap();
        assert!(dev.execute("vm_multi_f8_s4096", &[]).is_err());
        assert!(dev.execute("nope", &[]).is_err());
    }
}
