//! Per-thread device handles and the simulated multi-GPU pool.
//!
//! The paper runs on N Tesla V100s coordinated by Ray; our testbed is the
//! CPU PJRT plugin. A "device" here is a worker thread owning its own
//! `PjRtClient` (the crate's client is `Rc`-based and must not cross
//! threads) with a lazily-populated executable cache compiled from the
//! shared [`Registry`] HLO texts. The scheduling/batching logic above is
//! identical to what a real multi-accelerator deployment would use; see
//! DESIGN.md "Substitutions" for the fidelity argument.

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::runtime::launch::Value;
use crate::runtime::registry::{ExeSpec, Registry, TensorSpec};

/// Output of one device launch: flat f32 payload + wall time on device.
#[derive(Debug, Clone)]
pub struct LaunchOutput {
    pub data: Vec<f32>,
    pub device_time: Duration,
}

/// One simulated accelerator: thread-local PJRT client + exe cache.
pub struct DeviceRuntime {
    registry: Arc<Registry>,
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
    /// Cumulative time spent executing (for utilization metrics).
    busy: RefCell<Duration>,
}

impl DeviceRuntime {
    pub fn new(registry: Arc<Registry>) -> Result<Self> {
        // silence TfrtCpuClient created/destroyed info chatter unless the
        // user already configured TF logging
        if std::env::var_os("TF_CPP_MIN_LOG_LEVEL").is_none() {
            std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
        }
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
        Ok(DeviceRuntime {
            registry,
            client,
            cache: RefCell::new(HashMap::new()),
            busy: RefCell::new(Duration::ZERO),
        })
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn busy_time(&self) -> Duration {
        *self.busy.borrow()
    }

    /// Compile (or fetch cached) and execute `exe_name` with `inputs`.
    pub fn execute(&self, exe_name: &str, inputs: &[Value]) -> Result<LaunchOutput> {
        let spec = self.registry.get(exe_name)?;
        self.check_inputs(spec, inputs)?;
        self.ensure_compiled(spec)?;
        let cache = self.cache.borrow();
        let exe = cache.get(exe_name).expect("just compiled");

        let literals: Vec<xla::Literal> = inputs
            .iter()
            .zip(&spec.inputs)
            .map(|(v, ts)| literal_for_spec(ts, v))
            .collect::<Result<_>>()?;
        let t0 = Instant::now();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {exe_name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch output: {e:?}"))?;
        let dt = t0.elapsed();
        *self.busy.borrow_mut() += dt;

        // Artifacts are lowered with return_tuple=True → unwrap 1-tuple.
        let out = lit
            .to_tuple1()
            .map_err(|e| anyhow!("output not a 1-tuple: {e:?}"))?;
        let data = out
            .to_vec::<f32>()
            .map_err(|e| anyhow!("output to_vec: {e:?}"))?;
        let want: usize = spec.outputs[0].elements();
        if data.len() != want {
            return Err(anyhow!(
                "{exe_name}: output has {} elements, manifest says {want}",
                data.len()
            ));
        }
        Ok(LaunchOutput { data, device_time: dt })
    }

    fn check_inputs(&self, spec: &ExeSpec, inputs: &[Value]) -> Result<()> {
        if inputs.len() != spec.inputs.len() {
            return Err(anyhow!(
                "{}: {} inputs given, manifest wants {}",
                spec.name,
                inputs.len(),
                spec.inputs.len()
            ));
        }
        for (v, ts) in inputs.iter().zip(&spec.inputs) {
            v.check(ts).with_context(|| spec.name.clone())?;
        }
        Ok(())
    }

    fn ensure_compiled(&self, spec: &ExeSpec) -> Result<()> {
        if self.cache.borrow().contains_key(&spec.name) {
            return Ok(());
        }
        let proto = xla::HloModuleProto::parse_and_return_unverified_module(
            spec.hlo_text.as_bytes(),
        )
        .map_err(|e| anyhow!("parse HLO {}: {e:?}", spec.name))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", spec.name))?;
        self.cache.borrow_mut().insert(spec.name.clone(), exe);
        Ok(())
    }

    /// Pre-compile a set of executables (worker warmup).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.ensure_compiled(self.registry.get(n)?)?;
        }
        Ok(())
    }
}

fn value_to_literal(v: &Value) -> Result<xla::Literal> {
    Ok(match v {
        Value::F32(x) => xla::Literal::vec1(x),
        Value::I32(x) => xla::Literal::vec1(x),
        Value::U32(x) => xla::Literal::vec1(x),
    })
}

/// Build a literal with the exact ranked shape the manifest declares
/// (the lowered HLO has ranked parameters, e.g. `f32[128,8]`).
fn literal_for_spec(ts: &TensorSpec, v: &Value) -> Result<xla::Literal> {
    let flat = value_to_literal(v)?;
    if ts.shape.len() <= 1 {
        return Ok(flat);
    }
    let dims: Vec<i64> = ts.shape.iter().map(|&d| d as i64).collect();
    flat.reshape(&dims)
        .map_err(|e| anyhow!("reshape input '{}': {e:?}", ts.name))
}

/// Topology descriptor for the simulated cluster: how many device
/// workers the coordinator should spawn. (Each worker builds its own
/// [`DeviceRuntime`] on its own thread.)
#[derive(Debug, Clone)]
pub struct DevicePool {
    pub registry: Arc<Registry>,
    pub n_devices: usize,
}

impl DevicePool {
    pub fn new(registry: &Arc<Registry>, n_devices: usize) -> Result<Self> {
        if n_devices == 0 {
            return Err(anyhow!("device pool needs >= 1 device"));
        }
        Ok(DevicePool { registry: Arc::clone(registry), n_devices })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::registry::DType as D;
    use crate::runtime::registry::TensorSpec;

    #[test]
    fn pool_rejects_zero_devices() {
        // Registry::load needs artifacts; build a tiny fake instead.
        // DevicePool construction only checks n_devices.
        let dir = std::env::temp_dir()
            .join(format!("zmc_pool_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            format!(
                r#"{{"constants":{{"abi_version":1,"MAX_DIM":8,"MAX_PROG":48,
                   "STACK":16,"MAX_PARAM":16,"N_OPS":24}},
                   "executables":{{"t":{{"file":"t.hlo.txt","kind":"harmonic",
                   "samples":8,"n_fns":1,"dims":1,"tile":8,
                   "inputs":[],"outputs":[{{"dtype":"f32","shape":[2,1]}}]}}}}}}"#
            ),
        )
        .unwrap();
        std::fs::write(dir.join("t.hlo.txt"), "HloModule t\n").unwrap();
        let reg = Arc::new(Registry::load(&dir).unwrap());
        assert!(DevicePool::new(&reg, 0).is_err());
        assert_eq!(DevicePool::new(&reg, 4).unwrap().n_devices, 4);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn value_literal_roundtrip() {
        let v = Value::F32(vec![1.0, 2.0, 3.0]);
        let lit = value_to_literal(&v).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0]);
        let u = Value::U32(vec![7, 8]);
        let lit = value_to_literal(&u).unwrap();
        assert_eq!(lit.to_vec::<u32>().unwrap(), vec![7, 8]);
    }

    #[test]
    fn tensor_spec_elements() {
        let ts = TensorSpec { name: "k".into(), dtype: D::F32, shape: vec![4, 8] };
        assert_eq!(ts.elements(), 32);
    }
}
