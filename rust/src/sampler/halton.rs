//! Scrambled Halton low-discrepancy sequences — a quasi-Monte-Carlo
//! baseline the paper's plain-MC methods can be compared against
//! (extension beyond the paper; used by the CPU baseline and the
//! convergence-rate ablation in `tree_search_ablation`).
//!
//! Digit-scrambling uses a Philox-derived permutation seed per (dim,
//! digit) so the sequence stays deterministic and addressable like the
//! product RNG: `HaltonSeq::new(seed, dims)` then `point(idx)`.

use crate::abi::MAX_DIM;
use crate::sampler::philox::philox4x32;

/// First MAX_DIM primes — one base per dimension.
pub const PRIMES: [u32; MAX_DIM] = [2, 3, 5, 7, 11, 13, 17, 19];

/// Deterministic scrambled-Halton generator.
#[derive(Debug, Clone)]
pub struct HaltonSeq {
    seed: u64,
    dims: usize,
}

impl HaltonSeq {
    pub fn new(seed: u64, dims: usize) -> Self {
        assert!(dims <= MAX_DIM, "halton supports up to {MAX_DIM} dims");
        HaltonSeq { seed, dims }
    }

    /// Radical-inverse of `idx` in base `b` with per-digit scrambling.
    fn radical_inverse(&self, mut idx: u64, dim: usize) -> f64 {
        let b = PRIMES[dim] as u64;
        let mut inv = 0f64;
        let mut denom = 1f64;
        let mut digit_pos = 0u32;
        while idx > 0 {
            let digit = (idx % b) as u32;
            // scramble: permute the digit by a Philox-keyed offset that
            // depends on (seed, dim, digit position) — a positional
            // digit shift (Cranley-Patterson style per digit), which
            // preserves the equidistribution of the base-b digits.
            let r = philox4x32(
                [digit_pos, dim as u32, 0, 0],
                [
                    (self.seed & 0xFFFF_FFFF) as u32,
                    (self.seed >> 32) as u32,
                ],
            )[0] % PRIMES[dim];
            let scrambled = (digit + r) % PRIMES[dim];
            denom *= b as f64;
            inv += scrambled as f64 / denom;
            idx /= b;
            digit_pos += 1;
        }
        inv
    }

    /// The `idx`-th point of the sequence in [0,1)^dims.
    /// Index 0 maps to sequence element 1 (skip the all-zeros point).
    pub fn point(&self, idx: u64) -> [f64; MAX_DIM] {
        let mut out = [0f64; MAX_DIM];
        for d in 0..self.dims {
            out[d] = self.radical_inverse(idx + 1, d);
        }
        out
    }

    pub fn dims(&self) -> usize {
        self.dims
    }
}

/// QMC integration over a box with the scrambled Halton set (CPU path;
/// comparator for the MC methods — error O((log N)^D / N) vs O(1/√N)).
pub fn integrate_qmc<F: FnMut(&[f64]) -> f64>(
    seq: &HaltonSeq,
    bounds: &[(f64, f64)],
    samples: usize,
    mut f: F,
) -> f64 {
    let dims = bounds.len();
    assert!(dims <= seq.dims());
    let vol: f64 = bounds.iter().map(|(l, h)| h - l).product();
    let mut x = vec![0f64; dims];
    let mut sum = 0f64;
    for i in 0..samples {
        let u = seq.point(i as u64);
        for d in 0..dims {
            x[d] = bounds[d].0 + (bounds[d].1 - bounds[d].0) * u[d];
        }
        sum += f(&x);
    }
    vol * sum / samples as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unscrambled_base2_prefix() {
        // with seed chosen so the scramble offset for dim 0 is 0 at all
        // digit positions we can't rely on a specific seed; instead
        // check structural properties: points in range, deterministic.
        let h = HaltonSeq::new(7, 3);
        for i in 0..100 {
            let p = h.point(i);
            for d in 0..3 {
                assert!((0.0..1.0).contains(&p[d]), "{p:?}");
            }
        }
        assert_eq!(h.point(42), h.point(42));
    }

    #[test]
    fn golden_points_never_drift() {
        // bit-exact goldens (f64 bit patterns) pinning the scrambled
        // sequence: any change to the digit-shift derivation, the prime
        // table, or the radical-inverse accumulation order shows up
        // here before it silently re-addresses every QMC comparison
        let h = HaltonSeq::new(0xA5A5, 4);
        let cases: [(u64, [u64; 4]); 3] = [
            (0, [
                0x3FE0000000000000, // 0.5
                0x3FD5555555555555, // 1/3
                0x3FE3333333333333, // 3/5
                0x3FEB6DB6DB6DB6DB, // 6/7
            ]),
            (99, [
                0x3FCE000000000000,
                0x3FE37D5DC2E5A99D,
                0x3FDD2F1A9FBE76C9,
                0x3FBB9D7B26106B7A,
            ]),
            (4095, [
                0x3FBAB80000000000,
                0x3FE424AD65E08D17,
                0x3FE39756C93A7114,
                0x3FED5CEDCC4DAE92,
            ]),
        ];
        for (idx, want) in cases {
            let p = h.point(idx);
            for d in 0..4 {
                assert_eq!(
                    p[d].to_bits(),
                    want[d],
                    "idx={idx} d={d}: {} drifted",
                    p[d]
                );
            }
        }
        // a second seed, pinned too (scramble depends on the full key)
        let p = HaltonSeq::new(7, 3).point(42);
        let want =
            [0x3FB4000000000000u64, 0x3FE5555555555555, 0x3FD78D4FDF3B645A];
        for d in 0..3 {
            assert_eq!(p[d].to_bits(), want[d], "seed=7 d={d}");
        }
    }

    #[test]
    fn distinct_seeds_differ() {
        let a = HaltonSeq::new(1, 2).point(10);
        let b = HaltonSeq::new(2, 2).point(10);
        assert_ne!(a[..2], b[..2]);
    }

    #[test]
    fn equidistribution_first_moment() {
        // mean of each dim over N points → 1/2 much faster than MC
        let h = HaltonSeq::new(3, 4);
        let n = 4096;
        let mut mean = [0f64; 4];
        for i in 0..n {
            let p = h.point(i);
            for d in 0..4 {
                mean[d] += p[d];
            }
        }
        for d in 0..4 {
            let m = mean[d] / n as f64;
            assert!((m - 0.5).abs() < 0.01, "dim {d}: {m}");
        }
    }

    #[test]
    fn qmc_beats_mc_rate_on_smooth_integrand() {
        // ∫ x1*x2*x3 over [0,1]^3 = 1/8; QMC error at 4096 points must
        // beat the MC sigma ~ 0.0018 by a wide margin
        let h = HaltonSeq::new(11, 3);
        let got = integrate_qmc(
            &h,
            &[(0.0, 1.0), (0.0, 1.0), (0.0, 1.0)],
            4096,
            |x| x[0] * x[1] * x[2],
        );
        assert!((got - 0.125).abs() < 5e-4, "{got}");
    }

    #[test]
    fn qmc_with_volume_scaling() {
        let h = HaltonSeq::new(5, 2);
        let got = integrate_qmc(
            &h,
            &[(0.0, 2.0), (-1.0, 1.0)],
            8192,
            |x| x[0] + x[1],
        );
        // ∫∫ (x+y) over [0,2]x[-1,1] = 4; the positional digit-shift
        // scramble gives ~5e-3 error here — comfortably below the MC
        // sigma (~3.6e-2 at this budget) though above fully-permuted
        // scrambling.
        assert!((got - 4.0).abs() < 1.5e-2, "{got}");
    }
}
