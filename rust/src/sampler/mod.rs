//! Sample generation: the rust twin of `python/compile/philox.py`.
//!
//! The device kernels generate their own samples in-kernel; this module
//! exists so the *CPU baseline* and the test suite draw bit-identical
//! sample streams, and so the coordinator can reason about counter
//! chunking (`[base, base + samples)` ranges) without ever materializing
//! samples.

pub mod halton;
pub mod philox;

pub use philox::{philox4x32, philox4x32_lanes, u01, Philox};

use crate::abi::MAX_DIM;

/// One logical sample stream: `(seed, stream, trial)` — identical
/// addressing to the device kernels. `stream` distinguishes functions /
/// cubes / parameter points, `trial` independent repeats.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamKey {
    pub seed: [u32; 2],
    pub stream: u32,
    pub trial: u32,
}

impl StreamKey {
    pub fn new(seed: u64, stream: u32, trial: u32) -> Self {
        StreamKey {
            seed: [(seed & 0xFFFF_FFFF) as u32, (seed >> 32) as u32],
            stream,
            trial,
        }
    }

    /// The `dims` uniforms of sample `idx`, in [0, 1).
    ///
    /// Layout contract (must match `philox.uniform_tile` in python):
    /// dimension `d` comes from lane `d % 4` of the Philox block with
    /// counter `(idx, d / 4, stream, trial)`.
    pub fn point(&self, idx: u32, dims: usize) -> [f32; MAX_DIM] {
        debug_assert!(dims <= MAX_DIM);
        let mut out = [0f32; MAX_DIM];
        let mut d = 0;
        let mut j = 0u32;
        while d < dims {
            let block = philox4x32(
                [idx, j, self.stream, self.trial],
                [self.seed[0], self.seed[1]],
            );
            for lane in 0..4 {
                if d < dims {
                    out[d] = u01(block[lane]);
                    d += 1;
                }
            }
            j += 1;
        }
        out
    }

    /// Fill dimension-major uniform columns for samples
    /// `[base, base + n)`: `cols[d][i] = point(base + i, dims)[d]`,
    /// bit-identical to per-sample [`StreamKey::point`] but generated
    /// block-major — one tight loop per Philox block index with the key
    /// and lane routing hoisted out, which is what the emulator's plan
    /// path runs instead of a `point()` call per sample.
    pub fn fill_columns(
        &self,
        base: u32,
        n: usize,
        dims: usize,
        cols: &mut [Vec<f32>],
    ) {
        debug_assert!(dims <= MAX_DIM && cols.len() >= dims);
        let key = [self.seed[0], self.seed[1]];
        let mut d0 = 0usize;
        let mut j = 0u32;
        while d0 < dims {
            let lanes = (dims - d0).min(4);
            if lanes == 4 {
                // all four lanes live: write through four split columns
                let (c0, rest) = cols[d0..].split_first_mut().unwrap();
                let (c1, rest) = rest.split_first_mut().unwrap();
                let (c2, rest) = rest.split_first_mut().unwrap();
                let c3 = &mut rest[0];
                for i in 0..n {
                    let b = philox4x32(
                        [base.wrapping_add(i as u32), j, self.stream, self.trial],
                        key,
                    );
                    c0[i] = u01(b[0]);
                    c1[i] = u01(b[1]);
                    c2[i] = u01(b[2]);
                    c3[i] = u01(b[3]);
                }
            } else {
                for i in 0..n {
                    let b = philox4x32(
                        [base.wrapping_add(i as u32), j, self.stream, self.trial],
                        key,
                    );
                    for (lane, col) in
                        cols[d0..d0 + lanes].iter_mut().enumerate()
                    {
                        col[i] = u01(b[lane]);
                    }
                }
            }
            d0 += lanes;
            j += 1;
        }
    }

    /// Fill one `W`-lane block of uniforms structure-of-arrays:
    /// `blocks[d][i] = point(base + i, dims)[d]` for all `W` lanes,
    /// bit-identical to per-sample [`StreamKey::point`]. Unlike
    /// [`StreamKey::fill_columns`] the Philox blocks themselves are
    /// generated `W` at a time through [`philox4x32_lanes`], so the
    /// counter rounds autovectorize; this is the fused execution tier's
    /// sample source. Callers wanting fewer than `W` samples use a
    /// prefix of each row (trailing lanes hold well-defined uniforms for
    /// counters past the range — harmless and never read).
    pub fn fill_blocks<const W: usize>(
        &self,
        base: u32,
        dims: usize,
        blocks: &mut [[f32; W]],
    ) {
        debug_assert!(dims <= MAX_DIM && blocks.len() >= dims);
        let key = [self.seed[0], self.seed[1]];
        let mut c0 = [0u32; W];
        for (i, c) in c0.iter_mut().enumerate() {
            *c = base.wrapping_add(i as u32);
        }
        let mut d0 = 0usize;
        let mut j = 0u32;
        while d0 < dims {
            let words =
                philox4x32_lanes(&c0, [j, self.stream, self.trial], key);
            let live = (dims - d0).min(4);
            for (row, dst) in
                words.iter().zip(blocks[d0..d0 + live].iter_mut())
            {
                for i in 0..W {
                    dst[i] = u01(row[i]);
                }
            }
            d0 += live;
            j += 1;
        }
    }
}

/// Affine map from the unit cube to a box, dimension-wise.
#[inline]
pub fn scale_point(u: &[f32], lo: &[f64], hi: &[f64], out: &mut [f64]) {
    for d in 0..out.len() {
        out[d] = lo[d] + (hi[d] - lo[d]) * u[d] as f64;
    }
}

/// Volume of a box given per-dimension bounds.
pub fn volume(bounds: &[(f64, f64)]) -> f64 {
    bounds.iter().map(|(lo, hi)| hi - lo).product()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn point_layout_matches_block_lanes() {
        let k = StreamKey::new(0x0000_0002_0000_0001, 7, 3);
        let p = k.point(8, 8);
        let b0 = philox4x32([8, 0, 7, 3], [1, 2]);
        let b1 = philox4x32([8, 1, 7, 3], [1, 2]);
        for lane in 0..4 {
            assert_eq!(p[lane], u01(b0[lane]));
            assert_eq!(p[4 + lane], u01(b1[lane]));
        }
    }

    #[test]
    fn point_partial_dims() {
        let k = StreamKey::new(42, 0, 0);
        let p3 = k.point(5, 3);
        let p8 = k.point(5, 8);
        assert_eq!(&p3[..3], &p8[..3]);
        assert_eq!(p3[3..], [0f32; 5]); // unset dims stay zero
    }

    #[test]
    fn fill_columns_matches_point_bitwise() {
        let k = StreamKey::new(0xDEAD_BEEF_0000_0007, 11, 2);
        for dims in [1usize, 3, 4, 5, 8] {
            let n = 37;
            let base = 4090; // crosses a u32-ish boundary region
            let mut cols = vec![vec![0f32; n]; dims];
            k.fill_columns(base, n, dims, &mut cols);
            for i in 0..n {
                let p = k.point(base + i as u32, dims);
                for d in 0..dims {
                    assert_eq!(
                        cols[d][i].to_bits(),
                        p[d].to_bits(),
                        "dims={dims} i={i} d={d}"
                    );
                }
            }
        }
    }

    #[test]
    fn fill_blocks_matches_point_bitwise() {
        let k = StreamKey::new(0xDEAD_BEEF_0000_0007, 11, 2);
        for dims in [1usize, 3, 4, 5, 8] {
            const W: usize = 32;
            let base = u32::MAX - 10; // crosses the counter wraparound
            let mut blocks = vec![[0f32; W]; dims];
            k.fill_blocks(base, dims, &mut blocks);
            for i in 0..W {
                let p = k.point(base.wrapping_add(i as u32), dims);
                for d in 0..dims {
                    assert_eq!(
                        blocks[d][i].to_bits(),
                        p[d].to_bits(),
                        "dims={dims} i={i} d={d}"
                    );
                }
            }
        }
    }

    #[test]
    fn fill_blocks_matches_fill_columns_bitwise() {
        let k = StreamKey::new(0x0123_4567_89AB_CDEF, 5, 9);
        const W: usize = 16;
        let (base, dims) = (4090u32, 6usize);
        let mut blocks = vec![[0f32; W]; dims];
        let mut cols = vec![vec![0f32; W]; dims];
        k.fill_blocks(base, dims, &mut blocks);
        k.fill_columns(base, W, dims, &mut cols);
        for d in 0..dims {
            assert_eq!(&blocks[d][..], &cols[d][..], "d={d}");
        }
    }

    #[test]
    fn scale_and_volume() {
        let u = [0.5f32, 0.0, 1.0];
        let mut out = [0f64; 3];
        scale_point(&u, &[-1.0, 2.0, 0.0], &[1.0, 4.0, 10.0], &mut out);
        assert_eq!(out, [0.0, 2.0, 10.0]);
        assert_eq!(volume(&[(-1.0, 1.0), (2.0, 4.0)]), 4.0);
    }

    #[test]
    fn streams_differ_trials_differ() {
        let a = StreamKey::new(9, 1, 0).point(0, 4);
        let b = StreamKey::new(9, 2, 0).point(0, 4);
        let c = StreamKey::new(9, 1, 1).point(0, 4);
        assert_ne!(a, b);
        assert_ne!(a, c);
    }
}
