//! Philox-4x32-10 counter RNG — bit-exact twin of the device kernels.
//!
//! Verified against the Random123 known-answer vectors in
//! `spec/philox_kat.txt` (the same file the python tests parse) and,
//! transitively, against the Pallas kernels through the python suite.

const M0: u32 = 0xD251_1F53;
const M1: u32 = 0xCD9E_8D57;
const W0: u32 = 0x9E37_79B9;
const W1: u32 = 0xBB67_AE85;
const ROUNDS: u32 = 10;

#[inline(always)]
fn mulhilo(a: u32, b: u32) -> (u32, u32) {
    let p = a as u64 * b as u64;
    ((p >> 32) as u32, p as u32)
}

/// One Philox-4x32-10 block: 128-bit counter + 64-bit key -> 128 bits.
#[inline]
pub fn philox4x32(ctr: [u32; 4], key: [u32; 2]) -> [u32; 4] {
    let [mut c0, mut c1, mut c2, mut c3] = ctr;
    let [mut k0, mut k1] = key;
    for r in 0..ROUNDS {
        if r > 0 {
            k0 = k0.wrapping_add(W0);
            k1 = k1.wrapping_add(W1);
        }
        let (hi0, lo0) = mulhilo(M0, c0);
        let (hi1, lo1) = mulhilo(M1, c2);
        (c0, c1, c2, c3) = (hi1 ^ c1 ^ k0, lo1, hi0 ^ c3 ^ k1, lo0);
    }
    [c0, c1, c2, c3]
}

/// Map a u32 to f32 uniform in [0,1) using the top 24 bits (same mapping
/// as the kernels: exactly representable, never returns 1.0).
#[inline(always)]
pub fn u01(x: u32) -> f32 {
    (x >> 8) as f32 * (1.0 / 16_777_216.0)
}

/// `W` Philox-4x32-10 blocks evaluated side by side: lane `i` runs the
/// counter `[c0[i], c123[0], c123[1], c123[2]]` under `key`. The state
/// lives in fixed-width lane arrays and every round is a straight-line
/// loop over `0..W`, so rustc autovectorizes the widening 32x32->64
/// multiplies; each lane is bit-identical to [`philox4x32`] on the same
/// counter (asserted against the Random123 vectors in the tests below).
#[inline]
pub fn philox4x32_lanes<const W: usize>(
    c0: &[u32; W],
    c123: [u32; 3],
    key: [u32; 2],
) -> [[u32; W]; 4] {
    let mut x0 = *c0;
    let mut x1 = [c123[0]; W];
    let mut x2 = [c123[1]; W];
    let mut x3 = [c123[2]; W];
    let [mut k0, mut k1] = key;
    for r in 0..ROUNDS {
        if r > 0 {
            k0 = k0.wrapping_add(W0);
            k1 = k1.wrapping_add(W1);
        }
        for i in 0..W {
            let p0 = M0 as u64 * x0[i] as u64;
            let p1 = M1 as u64 * x2[i] as u64;
            x0[i] = (p1 >> 32) as u32 ^ x1[i] ^ k0;
            x1[i] = p1 as u32;
            x2[i] = (p0 >> 32) as u32 ^ x3[i] ^ k1;
            x3[i] = p0 as u32;
        }
    }
    [x0, x1, x2, x3]
}

/// Buffered iterator over one stream's uniforms — convenience for CPU
/// baselines that consume dimension-major samples.
pub struct Philox {
    key: [u32; 2],
    stream: u32,
    trial: u32,
    idx: u32,
    block_j: u32,
    buf: [u32; 4],
    lane: usize,
}

impl Philox {
    pub fn new(seed: u64, stream: u32, trial: u32) -> Self {
        Philox {
            key: [(seed & 0xFFFF_FFFF) as u32, (seed >> 32) as u32],
            stream,
            trial,
            idx: 0,
            block_j: 0,
            buf: [0; 4],
            lane: 4, // force refill on first draw
        }
    }

    /// Position at sample `idx` (used by chunked consumers).
    pub fn seek(&mut self, idx: u32) {
        self.idx = idx;
        self.block_j = 0;
        self.lane = 4;
    }

    /// Next uniform of the *current sample*; call `advance()` to move to
    /// the next sample (resetting the dimension cursor).
    pub fn next_dim(&mut self) -> f32 {
        if self.lane == 4 {
            self.buf = philox4x32(
                [self.idx, self.block_j, self.stream, self.trial],
                self.key,
            );
            self.block_j += 1;
            self.lane = 0;
        }
        let v = u01(self.buf[self.lane]);
        self.lane += 1;
        v
    }

    pub fn advance(&mut self) {
        self.idx = self.idx.wrapping_add(1);
        self.block_j = 0;
        self.lane = 4;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn load_kat() -> Vec<([u32; 4], [u32; 2], [u32; 4])> {
        let spec = Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("spec/philox_kat.txt");
        let text = std::fs::read_to_string(spec).expect("spec/philox_kat.txt");
        let mut rows = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (ins, outs) = line.split_once("->").unwrap();
            let w: Vec<u32> = ins
                .split_whitespace()
                .map(|s| u32::from_str_radix(s, 16).unwrap())
                .collect();
            let o: Vec<u32> = outs
                .split_whitespace()
                .map(|s| u32::from_str_radix(s, 16).unwrap())
                .collect();
            rows.push((
                [w[0], w[1], w[2], w[3]],
                [w[4], w[5]],
                [o[0], o[1], o[2], o[3]],
            ));
        }
        assert!(!rows.is_empty());
        rows
    }

    #[test]
    fn known_answer_vectors() {
        for (ctr, key, want) in load_kat() {
            assert_eq!(philox4x32(ctr, key), want);
        }
    }

    #[test]
    fn lanes_match_known_answer_vectors() {
        // every KAT counter, replicated across all lanes of the wide
        // kernel, must reproduce the scalar answer in every lane — and a
        // mixed-c0 block must match per-lane scalar calls bit-for-bit
        for (ctr, key, want) in load_kat() {
            let c0 = [ctr[0]; 8];
            let got = philox4x32_lanes(&c0, [ctr[1], ctr[2], ctr[3]], key);
            for lane in 0..8 {
                for w in 0..4 {
                    assert_eq!(got[w][lane], want[w], "word {w} lane {lane}");
                }
            }
        }
    }

    #[test]
    fn lanes_match_scalar_on_counter_runs() {
        // the fused tier's usage pattern: consecutive counters in c0,
        // broadcast c1..c3 — including a wraparound boundary
        for base in [0u32, 1000, u32::MAX - 3] {
            let mut c0 = [0u32; 16];
            for (i, c) in c0.iter_mut().enumerate() {
                *c = base.wrapping_add(i as u32);
            }
            let key = [0xDEAD_BEEF, 0x1234_5678];
            let got = philox4x32_lanes(&c0, [3, 7, 11], key);
            for (lane, &c) in c0.iter().enumerate() {
                let want = philox4x32([c, 3, 7, 11], key);
                for w in 0..4 {
                    assert_eq!(got[w][lane], want[w], "base={base} lane={lane}");
                }
            }
        }
    }

    #[test]
    fn u01_range_and_edges() {
        assert_eq!(u01(0), 0.0);
        assert!(u01(u32::MAX) < 1.0);
        assert!((u01(1 << 31) - 0.5).abs() < 1e-7);
    }

    #[test]
    fn iterator_matches_raw_blocks() {
        let mut p = Philox::new(0x0000_0002_0000_0001, 5, 1);
        p.seek(100);
        let b0 = philox4x32([100, 0, 5, 1], [1, 2]);
        let b1 = philox4x32([100, 1, 5, 1], [1, 2]);
        for lane in 0..4 {
            assert_eq!(p.next_dim(), u01(b0[lane]));
        }
        assert_eq!(p.next_dim(), u01(b1[0]));
        p.advance();
        let b = philox4x32([101, 0, 5, 1], [1, 2]);
        assert_eq!(p.next_dim(), u01(b[0]));
    }

    #[test]
    fn moments_sane() {
        let mut p = Philox::new(77, 0, 0);
        let n = 1 << 16;
        let mut sum = 0f64;
        let mut sq = 0f64;
        for _ in 0..n {
            let v = p.next_dim() as f64;
            sum += v;
            sq += v * v;
            p.advance();
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.005, "var={var}");
    }
}
