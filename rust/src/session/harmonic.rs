//! Fluent builder for the harmonic-family fast path (Fig. 1).

use anyhow::Result;

use crate::integrator::harmonic::{self, HarmonicBatch, HarmonicHandle};
use crate::integrator::multifunctions::MultiConfig;
use crate::integrator::spec::Estimate;

use super::multi::validate_multi_config;
use super::Session;

/// Chainable configuration for a batch of harmonic integrands over one
/// shared box, routed through the MXU-shaped `harmonic` artifact on
/// the session's [primary engine](Session::engine). Terminate with
/// [`run`](Self::run), [`run_trials`](Self::run_trials) or
/// [`submit`](Self::submit).
#[must_use = "builders do nothing until .run()/.submit()"]
pub struct HarmonicBuilder<'s> {
    session: &'s Session,
    batch: &'s HarmonicBatch,
    cfg: MultiConfig,
}

impl<'s> HarmonicBuilder<'s> {
    pub(crate) fn new(
        session: &'s Session,
        batch: &'s HarmonicBatch,
    ) -> Self {
        HarmonicBuilder { session, batch, cfg: MultiConfig::default() }
    }

    /// Samples per harmonic.
    pub fn samples(mut self, n: usize) -> Self {
        self.cfg.samples_per_fn = n;
        self
    }

    /// RNG seed shared by the batch.
    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Independent-repeat id ([`run_trials`](Self::run_trials)
    /// advances it per repeat).
    pub fn trial(mut self, trial: u32) -> Self {
        self.cfg.trial = trial;
        self
    }

    /// First Philox stream id; launch block `b` uses
    /// `stream_base + b`.
    pub fn stream_base(mut self, stream: u32) -> Self {
        self.cfg.stream_base = stream;
        self
    }

    /// Per-job retry budget on the engine.
    pub fn max_retries(mut self, n: u32) -> Self {
        self.cfg.max_retries = n;
        self
    }

    /// Force a specific harmonic executable.
    pub fn exe(mut self, name: impl Into<String>) -> Self {
        self.cfg.exe = Some(name.into());
        self
    }

    /// Replace the whole [`MultiConfig`] — the escape hatch for
    /// callers migrating from [`harmonic::integrate`].
    pub fn config(mut self, cfg: MultiConfig) -> Self {
        self.cfg = cfg;
        self
    }

    fn validated(self) -> Result<Self> {
        validate_multi_config(&self.cfg)?;
        Ok(self)
    }

    /// Integrate the batch; one [`Estimate`] per harmonic, in order.
    pub fn run(self) -> Result<Vec<Estimate>> {
        let b = self.validated()?;
        harmonic::integrate(b.session.engine(), b.batch, &b.cfg)
    }

    /// Independent repeats, one estimate vector per trial — all
    /// submitted up front so trials interleave across the workers.
    pub fn run_trials(self, trials: u32) -> Result<Vec<Vec<Estimate>>> {
        let b = self.validated()?;
        harmonic::integrate_trials(
            b.session.engine(),
            b.batch,
            &b.cfg,
            trials,
        )
    }

    /// Submit the batch without waiting.
    pub fn submit(self) -> Result<HarmonicHandle> {
        let b = self.validated()?;
        harmonic::submit(b.session.engine(), b.batch, &b.cfg)
    }
}
