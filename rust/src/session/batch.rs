//! Fluent builder for columnar batch runs (the 10⁵–10⁶ regime).

use anyhow::Result;

use crate::batch::{self, BatchConfig, BatchJobs, BatchResults};

use super::{Error, Session};

/// Chainable configuration for a streaming columnar batch — the
/// million-integrand counterpart of [`super::MultiBuilder`]. Terminate
/// with [`run`](Self::run); results are bit-identical to the boxed
/// `multifunctions` path on the same jobs and config.
#[must_use = "builders do nothing until .run()"]
pub struct BatchBuilder<'s> {
    session: &'s Session,
    jobs: &'s BatchJobs,
    cfg: BatchConfig,
}

impl<'s> BatchBuilder<'s> {
    pub(crate) fn new(session: &'s Session, jobs: &'s BatchJobs) -> Self {
        BatchBuilder { session, jobs, cfg: BatchConfig::default() }
    }

    /// Samples per function (rounded up to whole launches).
    pub fn samples(mut self, n: usize) -> Self {
        self.cfg.samples_per_fn = n;
        self
    }

    /// RNG seed shared by the batch.
    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Independent-repeat id.
    pub fn trial(mut self, trial: u32) -> Self {
        self.cfg.trial = trial;
        self
    }

    /// First Philox stream id; function `i` uses `stream_base + i`.
    pub fn stream_base(mut self, stream: u32) -> Self {
        self.cfg.stream_base = stream;
        self
    }

    /// Per-window retry budget on the engine.
    pub fn max_retries(mut self, n: u32) -> Self {
        self.cfg.max_retries = n;
        self
    }

    /// Force a specific executable (default: best fit by
    /// dims + samples).
    pub fn exe(mut self, name: impl Into<String>) -> Self {
        self.cfg.exe = Some(name.into());
        self
    }

    /// In-flight watermark: launch tasks per submission window (at
    /// most two windows ride the engine). Any value is bit-identical;
    /// it trades peak memory against submission overhead.
    pub fn watermark(mut self, n: usize) -> Self {
        self.cfg.watermark = n;
        self
    }

    /// Replace the whole [`BatchConfig`] (escape hatch mirroring
    /// [`super::MultiBuilder::config`]).
    pub fn config(mut self, cfg: BatchConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Integrate with streaming reduction; one estimate row per
    /// function, in order.
    pub fn run(self) -> Result<BatchResults> {
        if self.cfg.samples_per_fn == 0 {
            return Err(Error::ZeroSamples.into());
        }
        batch::integrate(self.session.exec(), self.jobs, &self.cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::integrator::spec::IntegralJob;

    #[test]
    fn zero_samples_is_rejected_before_submission() {
        let s = Session::builder().emulated().build().unwrap();
        let job =
            IntegralJob::parse("x1*x1", &[(0.0, 1.0)]).unwrap();
        let jobs = BatchJobs::scan(&job, &[]).unwrap();
        let err = s.batch(&jobs).samples(0).run().unwrap_err();
        assert_eq!(
            err.downcast_ref::<Error>(),
            Some(&Error::ZeroSamples)
        );
    }

    #[test]
    fn empty_batch_runs_to_empty_results() {
        let s = Session::builder().emulated().build().unwrap();
        let job = IntegralJob::parse("x1", &[(0.0, 1.0)]).unwrap();
        let jobs = BatchJobs::scan(&job, &[]).unwrap();
        let res = s.batch(&jobs).samples(1024).run().unwrap();
        assert!(res.is_empty());
    }
}
