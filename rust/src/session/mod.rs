//! One front door for the paper's three classes — the [`Session`]
//! façade and its fluent builders.
//!
//! The paper's promise is a stable surface: one object, one
//! `evaluate()`, whether you integrate a single function, a
//! heterogeneous batch of 10³ integrands, a parameter sweep, or a
//! stratified tree search. Before this module existed callers
//! hand-wired `Registry → DevicePool → Engine/DeviceCluster` and then
//! picked among module-level free functions, each with its own config
//! struct. A `Session` owns that construction once and hands out
//! chainable builders that terminate in `.run()` / `.submit()`:
//!
//! ```no_run
//! use zmc::prelude::*;
//!
//! let session = Session::builder()
//!     .artifacts("artifacts")
//!     .workers(2)
//!     .engines(1)
//!     .build()
//!     .unwrap();
//! let job = IntegralJob::parse("sin(x1)*x2", &[(0.0, 1.0), (0.0, 2.0)])
//!     .unwrap();
//! let est = session
//!     .multifunctions(std::slice::from_ref(&job))
//!     .samples(1 << 20)
//!     .seed(42)
//!     .run()
//!     .unwrap()[0];
//! println!("{est}");
//! ```
//!
//! | paper API | session builder |
//! |---|---|
//! | `ZMCintegral_multifunctions(fns).evaluate()` | [`Session::multifunctions`]`(&jobs).samples(n).run()` |
//! | `ZMCintegral_functional(f, grid).evaluate()` | [`Session::functional`]`(&job, &grid).samples(n).run()` |
//! | `ZMCintegral_normal(f).evaluate()` | [`Session::normal`]`(&job).depth(d).run()` |
//!
//! Sync and async (`.run()` vs `.submit() -> handle`), one engine and
//! N engines (`.engines(n)` at session build), one-shot and adaptive
//! (`.target_rel_err(..)`) are all the same call shape, and results
//! are bit-identical to the module-level free functions the builders
//! delegate to ([`crate::integrator::multifunctions::integrate`] and
//! friends — those remain supported as the thin compatibility layer,
//! proven equivalent by `tests/session_test.rs`).
//!
//! Builders validate before any device work is submitted; violations
//! surface as typed [`Error`]s recoverable with
//! `err.downcast_ref::<zmc::session::Error>()`.

use std::path::Path;
use std::sync::Arc;

use anyhow::Result;

use crate::cluster::chaos::FaultPlan as WireFaultPlan;
use crate::cluster::{DeviceCluster, LaunchExec, RemoteConfig};
use crate::config::JobConfig;
use crate::engine::{DeviceEngine, Engine};
use crate::integrator::harmonic::HarmonicBatch;
use crate::integrator::spec::IntegralJob;
use crate::runtime::device::DevicePool;
use crate::runtime::registry::Registry;
use crate::runtime::ExecTier;

mod batch;
mod functional;
mod harmonic;
mod job;
mod multi;
mod normal;

pub use self::batch::BatchBuilder;
pub use self::functional::FunctionalBuilder;
pub use self::harmonic::HarmonicBuilder;
pub use self::job::{validate_job, JobEvent, JobOutput};
pub use self::multi::MultiBuilder;
pub use self::normal::NormalBuilder;

/// Typed validation errors raised by the session builders before any
/// launch is submitted. They travel inside `anyhow::Error`; recover
/// the variant with `err.downcast_ref::<zmc::session::Error>()`.
#[derive(Debug, Clone, PartialEq)]
pub enum Error {
    /// `.samples(0)` — the run would evaluate nothing.
    ZeroSamples,
    /// Both `.target_rel_err(..)` and `.target_abs_err(..)` were set
    /// through the fluent knobs; pick one stopping rule per run. (A
    /// whole `MultiConfig` passed via the `.config()` escape hatch may
    /// combine both, keeping the free functions' stop-at-whichever-is-
    /// met semantics.)
    ConflictingTargets,
    /// An error target that is not finite and positive.
    InvalidTarget {
        /// The offending target value.
        value: f64,
    },
    /// A parameter grid point binds fewer values than the integrand
    /// reads.
    DimMismatch {
        /// Parameters the expression reads (`p0..p{expected-1}`).
        expected: usize,
        /// Values the offending grid point supplies.
        got: usize,
    },
    /// A parameter grid point exceeds the ABI's parameter-slot
    /// capacity ([`crate::abi::MAX_PARAM`]).
    TooManyParams {
        /// The ABI's parameter-slot capacity.
        max: usize,
        /// Values the offending grid point supplies.
        got: usize,
    },
    /// The tree-search variance heuristic needs >= 2 trials per cube.
    TooFewTrials {
        /// The configured trial count.
        got: u32,
    },
    /// A job-config field that does not apply to the job's class
    /// (e.g. error targets outside the multifunctions class).
    InapplicableOption {
        /// The offending option, in job-file spelling.
        option: &'static str,
        /// The class it does not apply to.
        class: &'static str,
    },
}

impl Error {
    /// Stable machine-readable code for this variant — the `"code"`
    /// field of the JSON [`ErrorPayload`] the CLI's `--json` exit path
    /// and the server's 4xx bodies emit. Codes are API: they never
    /// change meaning, clients switch on them instead of parsing the
    /// prose `Display` text.
    pub fn code(&self) -> &'static str {
        match self {
            Error::ZeroSamples => "zero_samples",
            Error::ConflictingTargets => "conflicting_targets",
            Error::InvalidTarget { .. } => "invalid_target",
            Error::DimMismatch { .. } => "dim_mismatch",
            Error::TooManyParams { .. } => "too_many_params",
            Error::TooFewTrials { .. } => "too_few_trials",
            Error::InapplicableOption { .. } => "inapplicable_option",
        }
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::ZeroSamples => {
                write!(f, "samples must be > 0")
            }
            Error::ConflictingTargets => write!(
                f,
                "conflicting error targets: set only one of \
                 target_rel_err / target_abs_err"
            ),
            Error::InvalidTarget { value } => write!(
                f,
                "error target must be finite and > 0 (got {value})"
            ),
            Error::DimMismatch { expected, got } => write!(
                f,
                "parameter grid point has {got} value(s) but the \
                 integrand reads {expected} parameter(s)"
            ),
            Error::TooManyParams { max, got } => write!(
                f,
                "parameter grid point has {got} value(s) but the ABI \
                 caps parameter slots at {max}"
            ),
            Error::TooFewTrials { got } => write!(
                f,
                "n_trials must be >= 2 for the variance heuristic \
                 (got {got})"
            ),
            Error::InapplicableOption { option, class } => write!(
                f,
                "'{option}' does not apply to the {class} class"
            ),
        }
    }
}

impl std::error::Error for Error {}

/// The one JSON error shape clients see: `{"code", "message"}`. The
/// CLI's `--json` failure exit and every server 4xx/5xx body carry it,
/// so clients switch on the stable `code` instead of parsing prose.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ErrorPayload {
    /// Stable machine-readable code ([`Error::code`] for builder
    /// errors; `"unsupported_version"`, `"bad_json"`, `"error"`, and
    /// the server's own codes otherwise).
    pub code: String,
    /// Human-readable description (the full `anyhow` context chain).
    pub message: String,
}

impl ErrorPayload {
    pub fn new(
        code: impl Into<String>,
        message: impl Into<String>,
    ) -> Self {
        ErrorPayload { code: code.into(), message: message.into() }
    }

    /// Classify an `anyhow` error into a payload: typed errors keep
    /// their stable code (recovered with `downcast_ref` through any
    /// context wrapping), everything else falls back to `"error"`.
    pub fn from_error(err: &anyhow::Error) -> Self {
        let code = if let Some(e) = err.downcast_ref::<Error>() {
            e.code()
        } else if err.is::<crate::config::UnsupportedVersion>() {
            "unsupported_version"
        } else if err.is::<crate::util::json::JsonError>() {
            "bad_json"
        } else {
            "error"
        };
        ErrorPayload { code: code.into(), message: format!("{err:#}") }
    }

    /// Wire codec: `{"code": .., "message": ..}`.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut m = std::collections::BTreeMap::new();
        m.insert("code".to_string(), Json::Str(self.code.clone()));
        m.insert("message".to_string(), Json::Str(self.message.clone()));
        Json::Obj(m)
    }

    /// Parse the [`to_json`](Self::to_json) shape.
    pub fn from_json(j: &crate::util::json::Json) -> Result<Self> {
        use crate::util::json::Json;
        use anyhow::Context as _;
        Ok(ErrorPayload {
            code: j
                .get("code")
                .and_then(Json::as_str)
                .context("error payload missing 'code'")?
                .to_string(),
            message: j
                .get("message")
                .and_then(Json::as_str)
                .context("error payload missing 'message'")?
                .to_string(),
        })
    }
}

/// The execution surface a session owns: a single persistent engine
/// or a cluster of them, both behind [`LaunchExec`].
enum ExecTopology {
    Engine(DeviceEngine),
    Cluster(DeviceCluster),
}

/// One per process (or one per independent workload): owns the
/// artifact [`Registry`], the [`DevicePool`] topology, and the
/// persistent engine(s), and hands out per-class builders. Everything
/// run through one session shares its warm executable caches.
pub struct Session {
    registry: Arc<Registry>,
    topology: ExecTopology,
    workers: usize,
    tier: Option<ExecTier>,
}

impl Session {
    /// Start configuring a session. Defaults: the `artifacts`
    /// directory with emulator fallback, 1 worker, 1 engine.
    pub fn builder() -> SessionBuilder {
        SessionBuilder::new()
    }

    /// Build a session sized by a job file: `workers` workers per
    /// engine, `num_engines` engines, default artifact resolution.
    pub fn from_job_config(cfg: &JobConfig) -> Result<Session> {
        Session::builder().job_config(cfg).build()
    }

    /// The artifact registry launches resolve against.
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Shared handle to the registry (for spawning sibling sessions).
    pub fn registry_arc(&self) -> Arc<Registry> {
        Arc::clone(&self.registry)
    }

    /// The submission surface: the engine for a 1-engine session, the
    /// sharding cluster otherwise. Everything generic over
    /// [`LaunchExec`] accepts this.
    pub fn exec(&self) -> &dyn LaunchExec {
        match &self.topology {
            ExecTopology::Engine(e) => e,
            ExecTopology::Cluster(c) => c,
        }
    }

    /// The primary persistent engine: the only engine of a 1-engine
    /// session, engine 0 of a cluster. The harmonic fast path (an
    /// MXU-shaped single-engine artifact) runs here.
    pub fn engine(&self) -> &DeviceEngine {
        match &self.topology {
            ExecTopology::Engine(e) => e,
            ExecTopology::Cluster(c) => c.engine(0),
        }
    }

    /// The cluster behind a multi-engine session, if any.
    pub fn cluster(&self) -> Option<&DeviceCluster> {
        match &self.topology {
            ExecTopology::Engine(_) => None,
            ExecTopology::Cluster(c) => Some(c),
        }
    }

    /// Engines behind this session (1 unless built with `.engines`).
    pub fn num_engines(&self) -> usize {
        match &self.topology {
            ExecTopology::Engine(_) => 1,
            ExecTopology::Cluster(c) => c.n_engines(),
        }
    }

    /// Remote worker connections behind this session (0 unless built
    /// with `.remote_engines`). Counted inside [`num_engines`]
    /// (Self::num_engines), not in addition to it.
    pub fn num_remote_engines(&self) -> usize {
        match &self.topology {
            ExecTopology::Engine(_) => 0,
            ExecTopology::Cluster(c) => c.n_remote(),
        }
    }

    /// Device workers per engine.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The emulator execution tier this session's launches run
    /// through: the builder's pin when set, otherwise the process-wide
    /// default ([`ExecTier::from_env`]). Moot under PJRT.
    pub fn execution_tier(&self) -> ExecTier {
        self.tier.unwrap_or_else(ExecTier::from_env)
    }

    /// `ZMCintegral_multifunctions`: a heterogeneous integrand batch.
    /// The builder borrows `jobs` — nothing is copied on the way to
    /// `.run()`.
    pub fn multifunctions<'a>(
        &'a self,
        jobs: &'a [IntegralJob],
    ) -> MultiBuilder<'a> {
        MultiBuilder::new(self, jobs)
    }

    /// Columnar batch execution for the 10⁵–10⁶ regime: deduped
    /// programs, struct-of-arrays jobs/results, bounded-watermark
    /// streaming reduction ([`crate::batch`]). Bit-identical to
    /// [`multifunctions`](Self::multifunctions) on the same jobs.
    pub fn batch<'a>(
        &'a self,
        jobs: &'a crate::batch::BatchJobs,
    ) -> BatchBuilder<'a> {
        BatchBuilder::new(self, jobs)
    }

    /// `ZMCintegral_functional`: one integrand over a parameter grid
    /// (one estimate per grid point, in `grid` order).
    pub fn functional<'a>(
        &'a self,
        job: &'a IntegralJob,
        grid: &'a [Vec<f64>],
    ) -> FunctionalBuilder<'a> {
        FunctionalBuilder::new(self, job, grid)
    }

    /// `ZMCintegral_normal`: stratified sampling + heuristic tree
    /// search on one integrand.
    pub fn normal<'a>(&'a self, job: &'a IntegralJob) -> NormalBuilder<'a> {
        NormalBuilder::new(self, job)
    }

    /// The harmonic-family fast path (the Fig. 1 workload).
    pub fn harmonic<'a>(
        &'a self,
        batch: &'a HarmonicBatch,
    ) -> HarmonicBuilder<'a> {
        HarmonicBuilder::new(self, batch)
    }
}

/// Where a session's registry comes from.
enum RegistrySource {
    /// Load `dir`; fall back to the CPU emulator registry when the
    /// manifest is absent (and the `pjrt` feature is off).
    Auto(String),
    /// Load `dir`; any failure is a hard error.
    Strict(String),
    /// The in-process CPU emulator registry.
    Emulated,
    /// A registry the caller already loaded.
    Provided(Arc<Registry>),
}

/// Fluent configuration for a [`Session`].
#[must_use = "call .build() to construct the Session"]
pub struct SessionBuilder {
    source: RegistrySource,
    workers: usize,
    engines: usize,
    remotes: Vec<String>,
    tier: Option<ExecTier>,
    remote_config: Option<RemoteConfig>,
    fault_plan: Option<Arc<WireFaultPlan>>,
}

impl SessionBuilder {
    fn new() -> Self {
        SessionBuilder {
            source: RegistrySource::Auto("artifacts".into()),
            workers: 1,
            engines: 1,
            remotes: Vec::new(),
            tier: None,
            remote_config: None,
            fault_plan: None,
        }
    }

    /// Load artifacts from `dir`; a missing or invalid artifact set is
    /// a hard error (no silent fallback).
    pub fn artifacts(mut self, dir: impl Into<String>) -> Self {
        self.source = RegistrySource::Strict(dir.into());
        self
    }

    /// Load artifacts from `dir` when its manifest exists; otherwise
    /// use the bit-compatible CPU emulator registry (the out-of-the-box
    /// offline path). A *present but invalid* artifact set still
    /// errors — falling back would silently compute against the wrong
    /// executables.
    pub fn artifacts_or_emulator(mut self, dir: impl Into<String>) -> Self {
        self.source = RegistrySource::Auto(dir.into());
        self
    }

    /// Use the in-process CPU emulator registry unconditionally.
    pub fn emulated(mut self) -> Self {
        self.source = RegistrySource::Emulated;
        self
    }

    /// Use a registry the caller already loaded (shared across
    /// sessions).
    pub fn registry(mut self, registry: Arc<Registry>) -> Self {
        self.source = RegistrySource::Provided(registry);
        self
    }

    /// Device workers per engine (clamped to >= 1).
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n.max(1);
        self
    }

    /// Engines in the session: 1 = single persistent engine, N > 1 = a
    /// [`DeviceCluster`] sharding every batch (bit-identical results
    /// at any count). Clamped to >= 1.
    pub fn engines(mut self, n: usize) -> Self {
        self.engines = n.max(1);
        self
    }

    /// Remote worker hosts (`host:port` of running `zmc worker`
    /// processes) joined into the session's cluster alongside its
    /// local engines. Any remotes force a [`DeviceCluster`] topology;
    /// at least one local engine is always kept so [`Session::engine`]
    /// (the harmonic fast path) stays valid. Bit-identity holds across
    /// topologies: the same task list yields the same estimates whether
    /// it runs locally, remotely, or mixed.
    pub fn remote_engines<I, S>(mut self, addrs: I) -> Self
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        self.remotes.extend(addrs.into_iter().map(Into::into));
        self
    }

    /// Pin every worker of this session to one emulator execution tier
    /// (default: the process-wide [`ExecTier::from_env`]).
    pub fn execution_tier(mut self, tier: ExecTier) -> Self {
        self.tier = Some(tier);
        self
    }

    /// Transport tuning for the session's remote engines (heartbeat
    /// cadence, reconnect backoff/budget). Only consulted when
    /// [`remote_engines`](Self::remote_engines) adds at least one
    /// worker; the registry digest is filled in automatically at
    /// build time unless this config pins one.
    pub fn remote_config(mut self, cfg: RemoteConfig) -> Self {
        self.remote_config = Some(cfg);
        self
    }

    /// Deterministic transport fault injection for the session's
    /// remote connections (tests; the `ZMC_CHAOS` env var offers the
    /// same schedule format without code changes). An explicit plan
    /// here wins over the env var.
    pub fn fault_plan(mut self, plan: Arc<WireFaultPlan>) -> Self {
        self.fault_plan = Some(plan);
        self
    }

    /// Apply a job file's topology (`workers`, `num_engines`,
    /// `remotes`), reconnect tuning, and execution tier when the file
    /// pins them.
    pub fn job_config(self, cfg: &JobConfig) -> Self {
        let mut b = self
            .workers(cfg.workers)
            .engines(cfg.num_engines)
            .remote_engines(cfg.remotes.iter().cloned());
        if cfg.reconnect_retries.is_some()
            || cfg.reconnect_backoff_ms.is_some()
        {
            let defaults = RemoteConfig::default();
            let retries = cfg
                .reconnect_retries
                .unwrap_or(defaults.reconnect_retries);
            b = b.remote_config(RemoteConfig {
                reconnect_retries: retries,
                reconnect: retries > 0,
                reconnect_backoff: cfg
                    .reconnect_backoff_ms
                    .map(std::time::Duration::from_millis)
                    .unwrap_or(defaults.reconnect_backoff),
                ..defaults
            });
        }
        match cfg.tier {
            Some(t) => b.execution_tier(t),
            None => b,
        }
    }

    /// Resolve just the registry — no workers are spawned. For
    /// inspection paths like the CLI's `info` subcommand.
    pub fn load_registry(self) -> Result<Arc<Registry>> {
        Self::resolve(self.source)
    }

    /// True when `build()`/`load_registry()` will resolve to the CPU
    /// emulator registry: an explicit [`emulated`](Self::emulated)
    /// source, or the [`artifacts_or_emulator`](Self::artifacts_or_emulator)
    /// fallback condition. The one place that decision lives — callers
    /// wanting to announce the fallback (the CLI's stderr note) ask
    /// here instead of re-deriving it.
    pub fn will_use_emulator(&self) -> bool {
        match &self.source {
            RegistrySource::Emulated => true,
            RegistrySource::Auto(dir) => auto_falls_back(dir),
            RegistrySource::Strict(_) | RegistrySource::Provided(_) => {
                false
            }
        }
    }

    fn resolve(source: RegistrySource) -> Result<Arc<Registry>> {
        Ok(match source {
            RegistrySource::Provided(r) => r,
            RegistrySource::Emulated => Arc::new(Registry::emulated()),
            RegistrySource::Strict(dir) => Arc::new(Registry::load(&dir)?),
            RegistrySource::Auto(dir) => {
                if auto_falls_back(&dir) {
                    Arc::new(Registry::emulated())
                } else {
                    Arc::new(Registry::load(&dir)?)
                }
            }
        })
    }

    /// Resolve the registry, build the device pool, and spawn the
    /// engine(s). Workers and executable caches stay warm for the
    /// session's lifetime.
    pub fn build(self) -> Result<Session> {
        let registry = Self::resolve(self.source)?;
        let mut pool = DevicePool::new(&registry, self.workers)?;
        if let Some(t) = self.tier {
            pool = pool.with_tier(t);
        }
        let topology = if !self.remotes.is_empty() {
            // remotes force a cluster; keep >= 1 local engine so
            // Session::engine() (the harmonic fast path) stays valid
            let mut rcfg = self.remote_config.unwrap_or_default();
            if rcfg.chaos.is_none() {
                rcfg.chaos = self
                    .fault_plan
                    .or_else(WireFaultPlan::from_env);
            }
            ExecTopology::Cluster(
                DeviceCluster::for_pool_with_remote_config(
                    &pool,
                    self.engines,
                    &self.remotes,
                    rcfg,
                )?,
            )
        } else if self.engines <= 1 {
            ExecTopology::Engine(Engine::for_pool(&pool)?)
        } else {
            ExecTopology::Cluster(DeviceCluster::for_pool(
                &pool,
                self.engines,
            )?)
        };
        Ok(Session {
            registry,
            topology,
            workers: self.workers,
            tier: self.tier,
        })
    }
}

/// The `Auto` source's fallback rule: no manifest on disk and no PJRT
/// build (a pjrt build without artifacts must hard-error instead).
fn auto_falls_back(dir: &str) -> bool {
    !Path::new(dir).join("manifest.json").exists()
        && !cfg!(feature = "pjrt")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_and_clamps() {
        let b = SessionBuilder::new().workers(0).engines(0);
        assert_eq!(b.workers, 1);
        assert_eq!(b.engines, 1);
        let b = SessionBuilder::new().workers(3).engines(4);
        assert_eq!(b.workers, 3);
        assert_eq!(b.engines, 4);
    }

    #[test]
    fn will_use_emulator_mirrors_resolution() {
        assert!(SessionBuilder::new().emulated().will_use_emulator());
        assert!(!SessionBuilder::new()
            .artifacts("artifacts")
            .will_use_emulator());
        // the Auto fallback fires exactly when no manifest exists and
        // the build is not pjrt
        let b = SessionBuilder::new()
            .artifacts_or_emulator("definitely/not/a/dir");
        assert_eq!(b.will_use_emulator(), !cfg!(feature = "pjrt"));
    }

    #[test]
    fn error_display_is_stable() {
        assert_eq!(Error::ZeroSamples.to_string(), "samples must be > 0");
        assert!(Error::DimMismatch { expected: 2, got: 0 }
            .to_string()
            .contains("2 parameter(s)"));
        assert!(Error::TooFewTrials { got: 1 }.to_string().contains(">= 2"));
    }

    #[test]
    fn error_codes_are_stable() {
        let cases: [(Error, &str); 7] = [
            (Error::ZeroSamples, "zero_samples"),
            (Error::ConflictingTargets, "conflicting_targets"),
            (Error::InvalidTarget { value: -1.0 }, "invalid_target"),
            (Error::DimMismatch { expected: 2, got: 1 }, "dim_mismatch"),
            (
                Error::TooManyParams { max: 16, got: 17 },
                "too_many_params",
            ),
            (Error::TooFewTrials { got: 1 }, "too_few_trials"),
            (
                Error::InapplicableOption {
                    option: "trials",
                    class: "normal",
                },
                "inapplicable_option",
            ),
        ];
        for (err, code) in cases {
            assert_eq!(err.code(), code);
        }
    }

    #[test]
    fn error_payload_classifies_and_round_trips() {
        // a typed session error keeps its code through context
        let err: anyhow::Error = Error::ZeroSamples.into();
        let err = err.context("while validating");
        let p = ErrorPayload::from_error(&err);
        assert_eq!(p.code, "zero_samples");
        assert!(p.message.contains("while validating"));

        // an unknown-version config error is typed too
        let err = crate::config::JobConfig::from_json_text(
            r#"{"v": 9, "functions": []}"#,
        )
        .unwrap_err();
        assert_eq!(
            ErrorPayload::from_error(&err).code,
            "unsupported_version"
        );

        // malformed JSON types as bad_json
        let err =
            crate::config::JobConfig::from_json_text("{nope").unwrap_err();
        assert_eq!(ErrorPayload::from_error(&err).code, "bad_json");

        // untyped errors fall back to "error"
        let plain = anyhow::anyhow!("something else");
        assert_eq!(ErrorPayload::from_error(&plain).code, "error");

        // codec round trip
        let p = ErrorPayload::new("queue_full", "try later \"soon\"");
        let j = crate::util::json::Json::parse(&p.to_json().to_string())
            .unwrap();
        assert_eq!(ErrorPayload::from_json(&j).unwrap(), p);
    }

    #[test]
    fn execution_tier_pins_and_round_trips() {
        let s = Session::builder()
            .emulated()
            .execution_tier(ExecTier::Plan)
            .build()
            .unwrap();
        assert_eq!(s.execution_tier(), ExecTier::Plan);
        // a job file's tier flows through .job_config()
        let cfg = crate::config::JobConfig::from_json_text(
            r#"{"tier": "naive",
                 "functions": [{"expr": "x1", "bounds": [[0, 1]]}]}"#,
        )
        .unwrap();
        let b = SessionBuilder::new().emulated().job_config(&cfg);
        assert_eq!(b.tier, Some(ExecTier::Naive));
        // unpinned sessions report the process-wide default
        let s = Session::builder().emulated().build().unwrap();
        assert_eq!(s.execution_tier(), ExecTier::from_env());
    }

    #[test]
    fn emulated_session_topology_accessors() {
        let s = Session::builder().emulated().workers(2).build().unwrap();
        assert_eq!(s.num_engines(), 1);
        assert_eq!(s.workers(), 2);
        assert!(s.cluster().is_none());
        assert_eq!(s.engine().n_workers(), 2);

        let c =
            Session::builder().emulated().engines(3).build().unwrap();
        assert_eq!(c.num_engines(), 3);
        assert!(c.cluster().is_some());
        // no remotes configured anywhere above
        assert_eq!(s.num_remote_engines(), 0);
        assert_eq!(c.num_remote_engines(), 0);
    }
}
