//! Fluent builder for `ZMCintegral_normal` tree-search integration.

use anyhow::Result;

use crate::integrator::normal::{self, NormalConfig, NormalResult};
use crate::integrator::spec::IntegralJob;

use super::{Error, Session};

/// Chainable configuration for stratified sampling + heuristic tree
/// search on one integrand. Terminate with [`run`](Self::run); knobs
/// resolve into the same [`NormalConfig`] the free function takes, so
/// results are bit-identical to the legacy path (and to any engine
/// count — [`normal::integrate`] is generic over
/// [`crate::cluster::LaunchExec`]).
#[must_use = "builders do nothing until .run()"]
pub struct NormalBuilder<'s> {
    session: &'s Session,
    job: &'s IntegralJob,
    cfg: NormalConfig,
}

impl<'s> NormalBuilder<'s> {
    pub(crate) fn new(session: &'s Session, job: &'s IntegralJob) -> Self {
        NormalBuilder { session, job, cfg: NormalConfig::default() }
    }

    /// Initial divisions per dimension (`k^D` starting cubes).
    pub fn divisions(mut self, k: usize) -> Self {
        self.cfg.initial_divisions = k;
        self
    }

    /// Independent evaluations per cube per level (>= 2 — the variance
    /// heuristic needs a spread).
    pub fn trials(mut self, n: u32) -> Self {
        self.cfg.n_trials = n;
        self
    }

    /// Flag threshold: `mean(std) + sigma_mult * std(std)`.
    pub fn sigma_mult(mut self, s: f64) -> Self {
        self.cfg.sigma_mult = s;
        self
    }

    /// Maximum refinement depth (0 = no refinement).
    pub fn depth(mut self, d: usize) -> Self {
        self.cfg.max_depth = d;
        self
    }

    /// Subdivide at most this many dimensions per split.
    pub fn max_split_dims(mut self, d: usize) -> Self {
        self.cfg.max_split_dims = d;
        self
    }

    /// RNG seed for the cube trial streams.
    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Per-level retry budget on the engine.
    pub fn max_retries(mut self, n: u32) -> Self {
        self.cfg.max_retries = n;
        self
    }

    /// Force a specific stratified executable.
    pub fn exe(mut self, name: impl Into<String>) -> Self {
        self.cfg.exe = Some(name.into());
        self
    }

    /// Replace the whole [`NormalConfig`] — the escape hatch for
    /// callers migrating from [`normal::integrate`].
    pub fn config(mut self, cfg: NormalConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Run the tree search; returns the estimate plus per-level tree
    /// diagnostics.
    pub fn run(self) -> Result<NormalResult> {
        if self.cfg.n_trials < 2 {
            return Err(
                Error::TooFewTrials { got: self.cfg.n_trials }.into()
            );
        }
        normal::integrate(self.session.exec(), self.job, &self.cfg)
    }
}
