//! Fluent builder for `ZMCintegral_multifunctions` batches.

use anyhow::Result;

use crate::adaptive::Allocation;
use crate::integrator::multifunctions::{self, MultiConfig, MultiHandle};
use crate::integrator::spec::{Estimate, IntegralJob};

use super::{Error, Session};

/// Chainable configuration for a heterogeneous integrand batch.
/// Terminate with [`run`](Self::run), [`run_trials`](Self::run_trials)
/// or [`submit`](Self::submit); knobs resolve into the same
/// [`MultiConfig`] the free functions take, so results are
/// bit-identical to the legacy path.
#[must_use = "builders do nothing until .run()/.submit()"]
pub struct MultiBuilder<'s> {
    session: &'s Session,
    jobs: &'s [IntegralJob],
    cfg: MultiConfig,
    /// False once the whole config came through [`config`](Self::config):
    /// the escape hatch keeps the free functions' target semantics
    /// (rel and abs may be combined), while the fluent target knobs
    /// enforce one stopping rule per run.
    knob_targets: bool,
}

impl<'s> MultiBuilder<'s> {
    pub(crate) fn new(session: &'s Session, jobs: &'s [IntegralJob]) -> Self {
        MultiBuilder {
            session,
            jobs,
            cfg: MultiConfig::default(),
            knob_targets: true,
        }
    }

    /// Target samples per function (the per-function budget cap in
    /// adaptive mode).
    pub fn samples(mut self, n: usize) -> Self {
        self.cfg.samples_per_fn = n;
        self
    }

    /// RNG seed shared by the batch.
    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Independent-repeat id of this batch ([`run_trials`](Self::run_trials)
    /// advances it per repeat).
    pub fn trial(mut self, trial: u32) -> Self {
        self.cfg.trial = trial;
        self
    }

    /// First Philox stream id; function `i` uses `stream_base + i`.
    pub fn stream_base(mut self, stream: u32) -> Self {
        self.cfg.stream_base = stream;
        self
    }

    /// Per-job retry budget on the engine.
    pub fn max_retries(mut self, n: u32) -> Self {
        self.cfg.max_retries = n;
        self
    }

    /// Force a specific executable (default: best fit by
    /// dims + samples).
    pub fn exe(mut self, name: impl Into<String>) -> Self {
        self.cfg.exe = Some(name.into());
        self
    }

    /// Stop refining a function once `std_err <= target * |I|`.
    /// Setting an error target switches the run to the adaptive
    /// pilot-then-refine loop ([`crate::adaptive`]). Pass `None` to
    /// clear (handy when forwarding an optional CLI flag). Via the
    /// fluent knobs, set at most one of the rel/abs targets —
    /// combining both (stop at whichever is met) stays available
    /// through [`config`](Self::config).
    pub fn target_rel_err(mut self, target: impl Into<Option<f64>>) -> Self {
        self.cfg.target_rel_err = target.into();
        self.knob_targets = true;
        self
    }

    /// Stop refining a function once `std_err <= target` (absolute).
    /// Same one-target-per-run rule as
    /// [`target_rel_err`](Self::target_rel_err).
    pub fn target_abs_err(mut self, target: impl Into<Option<f64>>) -> Self {
        self.cfg.target_abs_err = target.into();
        self.knob_targets = true;
        self
    }

    /// Maximum refinement rounds after the pilot (adaptive mode).
    pub fn max_rounds(mut self, n: usize) -> Self {
        self.cfg.max_rounds = n;
        self
    }

    /// Samples per function in the adaptive pilot pass.
    pub fn pilot_samples(mut self, n: usize) -> Self {
        self.cfg.pilot_samples = n;
        self
    }

    /// How refinement rounds distribute the budget (adaptive mode).
    pub fn allocation(mut self, allocation: Allocation) -> Self {
        self.cfg.allocation = allocation;
        self
    }

    /// Replace the whole [`MultiConfig`] — the escape hatch for
    /// callers migrating from the free functions (the other knobs
    /// edit the same struct field-by-field). A config supplied here
    /// keeps the free functions' semantics exactly, including a
    /// combined rel+abs error target (stop at whichever is met).
    pub fn config(mut self, cfg: MultiConfig) -> Self {
        self.cfg = cfg;
        self.knob_targets = false;
        self
    }

    fn validated(self) -> Result<Self> {
        validate_multi_config(&self.cfg)?;
        if self.knob_targets
            && self.cfg.target_rel_err.is_some()
            && self.cfg.target_abs_err.is_some()
        {
            return Err(Error::ConflictingTargets.into());
        }
        Ok(self)
    }

    /// Integrate synchronously; one [`Estimate`] per job, in order.
    pub fn run(self) -> Result<Vec<Estimate>> {
        let b = self.validated()?;
        multifunctions::integrate(b.session.exec(), b.jobs, &b.cfg)
    }

    /// Independent repeats (the paper's "10 independent evaluations"):
    /// `trials` estimate vectors, each from a disjoint trial stream.
    pub fn run_trials(self, trials: u32) -> Result<Vec<Vec<Estimate>>> {
        let b = self.validated()?;
        multifunctions::integrate_trials(
            b.session.exec(),
            b.jobs,
            &b.cfg,
            trials,
        )
    }

    /// Submit asynchronously; independent batches ride the warm
    /// engine(s) concurrently and are awaited per-handle.
    pub fn submit(self) -> Result<MultiHandle> {
        let b = self.validated()?;
        multifunctions::submit(b.session.exec(), b.jobs, &b.cfg)
    }
}

/// Shared [`MultiConfig`] validation for the multifunction, functional
/// and harmonic builders: a run must draw samples and any error target
/// must be a usable number. (The one-target-per-run rule is specific
/// to [`MultiBuilder`]'s fluent knobs — a whole config passed through
/// an escape hatch keeps the free functions' combined-target
/// semantics.)
pub(crate) fn validate_multi_config(cfg: &MultiConfig) -> Result<()> {
    if cfg.samples_per_fn == 0 {
        return Err(Error::ZeroSamples.into());
    }
    for target in
        [cfg.target_rel_err, cfg.target_abs_err].into_iter().flatten()
    {
        if !target.is_finite() || target <= 0.0 {
            return Err(Error::InvalidTarget { value: target }.into());
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_validation_rules() {
        let ok = MultiConfig::default();
        assert!(validate_multi_config(&ok).is_ok());

        let zero = MultiConfig { samples_per_fn: 0, ..ok.clone() };
        let err = validate_multi_config(&zero).unwrap_err();
        assert_eq!(err.downcast_ref::<Error>(), Some(&Error::ZeroSamples));

        // a combined rel+abs target is *shared-validation* legal — the
        // adaptive driver stops at whichever is met; only the fluent
        // knob path of MultiBuilder rejects the combination
        let both = MultiConfig {
            target_rel_err: Some(1e-2),
            target_abs_err: Some(1e-3),
            ..ok.clone()
        };
        assert!(validate_multi_config(&both).is_ok());

        let bad = MultiConfig { target_rel_err: Some(-0.5), ..ok };
        let err = validate_multi_config(&bad).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<Error>(),
            Some(Error::InvalidTarget { .. })
        ));
    }
}
