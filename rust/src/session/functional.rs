//! Fluent builder for `ZMCintegral_functional` parameter scans.

use anyhow::Result;

use crate::abi::MAX_PARAM;
use crate::integrator::functional;
use crate::integrator::multifunctions::{MultiConfig, MultiHandle};
use crate::integrator::spec::{Estimate, IntegralJob};

use super::multi::validate_multi_config;
use super::{Error, Session};

/// Chainable configuration for one integrand swept over a parameter
/// grid (each grid point is its own packed integrand with its own
/// Philox stream — compilation happens once, not per point).
/// Terminate with [`run`](Self::run) or [`submit`](Self::submit).
#[must_use = "builders do nothing until .run()/.submit()"]
pub struct FunctionalBuilder<'s> {
    session: &'s Session,
    job: &'s IntegralJob,
    thetas: &'s [Vec<f64>],
    cfg: MultiConfig,
}

impl<'s> FunctionalBuilder<'s> {
    pub(crate) fn new(
        session: &'s Session,
        job: &'s IntegralJob,
        grid: &'s [Vec<f64>],
    ) -> Self {
        FunctionalBuilder {
            session,
            job,
            thetas: grid,
            cfg: MultiConfig::default(),
        }
    }

    /// Samples per grid point.
    pub fn samples(mut self, n: usize) -> Self {
        self.cfg.samples_per_fn = n;
        self
    }

    /// RNG seed shared by the scan.
    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Independent-repeat id of this scan.
    pub fn trial(mut self, trial: u32) -> Self {
        self.cfg.trial = trial;
        self
    }

    /// First Philox stream id; grid point `i` uses `stream_base + i`.
    pub fn stream_base(mut self, stream: u32) -> Self {
        self.cfg.stream_base = stream;
        self
    }

    /// Per-job retry budget on the engine.
    pub fn max_retries(mut self, n: u32) -> Self {
        self.cfg.max_retries = n;
        self
    }

    /// Force a specific executable (default: best fit by
    /// dims + samples).
    pub fn exe(mut self, name: impl Into<String>) -> Self {
        self.cfg.exe = Some(name.into());
        self
    }

    /// Replace the whole [`MultiConfig`] — the escape hatch for
    /// callers migrating from [`functional::scan`].
    pub fn config(mut self, cfg: MultiConfig) -> Self {
        self.cfg = cfg;
        self
    }

    fn validated(self) -> Result<Self> {
        validate_multi_config(&self.cfg)?;
        let expected = self.job.expr.n_params();
        for theta in self.thetas {
            if theta.len() > MAX_PARAM {
                return Err(Error::TooManyParams {
                    max: MAX_PARAM,
                    got: theta.len(),
                }
                .into());
            }
            if theta.len() < expected {
                return Err(Error::DimMismatch {
                    expected,
                    got: theta.len(),
                }
                .into());
            }
        }
        Ok(self)
    }

    /// Integrate at every grid point; one [`Estimate`] per point, in
    /// grid order.
    pub fn run(self) -> Result<Vec<Estimate>> {
        let b = self.validated()?;
        functional::scan(b.session.exec(), b.job, b.thetas, &b.cfg)
    }

    /// Submit the scan without waiting; points ride the warm engine(s)
    /// concurrently with any other in-flight work.
    pub fn submit(self) -> Result<MultiHandle> {
        let b = self.validated()?;
        functional::submit_scan(b.session.exec(), b.job, b.thetas, &b.cfg)
    }
}
