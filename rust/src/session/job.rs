//! Class-independent job execution: run a parsed
//! [`JobConfig`](crate::config::JobConfig) on a [`Session`], streaming
//! progress events.
//!
//! The CLI's `zmc run` (plain and `--json`) and the server's
//! `POST /v1/jobs` stream are the same computation over the same wire
//! schema; this module is that one computation. [`Session::run_job`]
//! dispatches a config to the class builders with the *exact*
//! submit/wait choreography of the module-level free functions —
//! non-adaptive trials submit up front and are awaited in order,
//! adaptive trials run sequentially on consecutive trial ids — so the
//! estimates are bit-identical to every other entry point.
//! [`Session::run_job_observed`] additionally surfaces a [`JobEvent`]
//! after every adaptive round and every finished trial; observers see
//! pure snapshots ([`crate::adaptive::RoundObserver`]) and can never
//! perturb the result.

use anyhow::Result;

use crate::adaptive;
use crate::config::{JobClass, JobConfig};
use crate::integrator::functional;
use crate::integrator::multifunctions::{self, MultiConfig, MultiHandle};
use crate::integrator::normal::NormalResult;
use crate::integrator::spec::Estimate;
use crate::util::json::Json;

use super::multi::validate_multi_config;
use super::{Error, Session};

/// Everything a finished job produced.
#[derive(Debug, Clone)]
pub struct JobOutput {
    /// `per_trial[t][i]` is function (or grid point) `i` of trial `t`.
    /// The normal class contributes one trial with one estimate.
    pub per_trial: Vec<Vec<Estimate>>,
    /// Tree-search diagnostics (`"class": "normal"` only).
    pub normal: Option<NormalResult>,
}

/// One progress event of a running job. Borrows the runner's estimate
/// buffers; call [`frames`](Self::frames) (or clone) to keep data.
#[derive(Debug, Clone, Copy)]
pub enum JobEvent<'a> {
    /// An adaptive round finished (pilot = round 1): the current
    /// per-function snapshot. Only the multifunctions class with an
    /// error target emits these.
    Round { trial: u32, round: u32, estimates: &'a [Estimate] },
    /// A trial finished; `estimates` are final for this trial.
    Trial { trial: u32, estimates: &'a [Estimate] },
}

impl JobEvent<'_> {
    /// The event as wire frames: one JSON object per function, the
    /// [`Estimate::to_json`] shape annotated with `fn`/`trial` and
    /// either `round` (in-flight snapshot) or `"final": true`
    /// (finished trial). `zmc run --json` prints these one per line;
    /// the server streams them as chunked lines with a job `id` added.
    pub fn frames(&self) -> Vec<Json> {
        let annotate = |estimates: &[Estimate],
                        trial: u32,
                        extra: (&str, Json)| {
            estimates
                .iter()
                .enumerate()
                .map(|(i, e)| {
                    let Json::Obj(mut m) = e.to_json() else {
                        unreachable!("Estimate::to_json is an object");
                    };
                    m.insert("fn".to_string(), Json::Num(i as f64));
                    m.insert("trial".to_string(), Json::Num(trial as f64));
                    m.insert(extra.0.to_string(), extra.1.clone());
                    Json::Obj(m)
                })
                .collect()
        };
        match *self {
            JobEvent::Round { trial, round, estimates } => annotate(
                estimates,
                trial,
                ("round", Json::Num(round as f64)),
            ),
            JobEvent::Trial { trial, estimates } => {
                annotate(estimates, trial, ("final", Json::Bool(true)))
            }
        }
    }
}

/// Pre-flight checks shared by [`Session::run_job`] and the server's
/// 400 path: class-inapplicable options, sampling rules, tree-search
/// trial minima — every violation a config can carry surfaces here as
/// a typed [`Error`] *before* any launch is submitted or any response
/// byte is streamed.
pub fn validate_job(cfg: &JobConfig) -> Result<()> {
    if !matches!(cfg.class, JobClass::Multifunctions)
        && (cfg.target_rel_err.is_some() || cfg.target_abs_err.is_some())
    {
        return Err(Error::InapplicableOption {
            option: "target_rel_err/target_abs_err",
            class: cfg.class.name(),
        }
        .into());
    }
    match &cfg.class {
        JobClass::Multifunctions | JobClass::Functional { .. } => {
            validate_multi_config(&multi_config(cfg))
        }
        JobClass::Normal(p) => {
            if cfg.trials > 1 {
                return Err(Error::InapplicableOption {
                    option: "trials",
                    class: "normal",
                }
                .into());
            }
            if p.n_trials < 2 {
                return Err(
                    Error::TooFewTrials { got: p.n_trials }.into()
                );
            }
            Ok(())
        }
    }
}

/// The multifunction/functional sampling config a job file resolves
/// to. `max_rounds: None` keeps the [`MultiConfig`] default, matching
/// what the fluent builders do when the knob is untouched.
fn multi_config(cfg: &JobConfig) -> MultiConfig {
    let defaults = MultiConfig::default();
    MultiConfig {
        samples_per_fn: cfg.samples_per_fn,
        seed: cfg.seed,
        target_rel_err: cfg.target_rel_err,
        target_abs_err: cfg.target_abs_err,
        max_rounds: cfg.max_rounds.unwrap_or(defaults.max_rounds),
        num_engines: cfg.num_engines,
        ..defaults
    }
}

impl Session {
    /// Run a job file on this session; estimates are bit-identical to
    /// the class builders (and free functions) with the same config.
    /// Class-inapplicable fields are typed
    /// [`Error::InapplicableOption`]s, raised before any launch.
    pub fn run_job(&self, cfg: &JobConfig) -> Result<JobOutput> {
        self.run_job_observed(cfg, &mut |_| {})
    }

    /// [`run_job`](Self::run_job) with a progress observer: called
    /// after every adaptive round and every finished trial. Observing
    /// never changes the returned estimates.
    pub fn run_job_observed(
        &self,
        cfg: &JobConfig,
        observe: &mut dyn FnMut(JobEvent<'_>),
    ) -> Result<JobOutput> {
        validate_job(cfg)?;
        match &cfg.class {
            JobClass::Multifunctions => {
                let mcfg = multi_config(cfg);
                let per_trial = if mcfg.is_adaptive() {
                    self.run_adaptive_trials(cfg, &mcfg, observe)?
                } else {
                    // mirror integrate_trials: submit every trial up
                    // front, await in order
                    let handles: Vec<MultiHandle> = (0..cfg.trials)
                        .map(|t| {
                            let c = MultiConfig {
                                trial: mcfg.trial + t,
                                ..mcfg.clone()
                            };
                            multifunctions::submit(
                                self.exec(),
                                &cfg.jobs,
                                &c,
                            )
                        })
                        .collect::<Result<_>>()?;
                    wait_trials(handles, observe)?
                };
                Ok(JobOutput { per_trial, normal: None })
            }
            JobClass::Functional { axes } => {
                let mcfg = multi_config(cfg);
                let points = functional::grid(axes);
                let handles: Vec<MultiHandle> = (0..cfg.trials)
                    .map(|t| {
                        let c = MultiConfig {
                            trial: mcfg.trial + t,
                            ..mcfg.clone()
                        };
                        self.functional(&cfg.jobs[0], &points)
                            .config(c)
                            .submit()
                    })
                    .collect::<Result<_>>()?;
                Ok(JobOutput {
                    per_trial: wait_trials(handles, observe)?,
                    normal: None,
                })
            }
            JobClass::Normal(p) => {
                let result = self
                    .normal(&cfg.jobs[0])
                    .divisions(p.divisions)
                    .trials(p.n_trials)
                    .sigma_mult(p.sigma_mult)
                    .depth(p.depth)
                    .max_split_dims(p.max_split_dims)
                    .seed(cfg.seed)
                    .run()?;
                let ests = vec![result.estimate];
                observe(JobEvent::Trial { trial: 0, estimates: &ests });
                Ok(JobOutput {
                    per_trial: vec![ests],
                    normal: Some(result),
                })
            }
        }
    }

    /// The adaptive arm of the multifunctions class: trials run
    /// sequentially on consecutive trial ids (exactly
    /// `integrate_trials`' choreography), each through the observed
    /// driver so every round streams.
    fn run_adaptive_trials(
        &self,
        cfg: &JobConfig,
        mcfg: &MultiConfig,
        observe: &mut dyn FnMut(JobEvent<'_>),
    ) -> Result<Vec<Vec<Estimate>>> {
        let mut per_trial = Vec::with_capacity(cfg.trials as usize);
        for t in 0..cfg.trials {
            let c = MultiConfig { trial: mcfg.trial + t, ..mcfg.clone() };
            let mut on_round = |round: usize, snap: &[Estimate]| {
                observe(JobEvent::Round {
                    trial: t,
                    round: round as u32,
                    estimates: snap,
                });
            };
            let ests = adaptive::integrate_observed(
                self.exec(),
                &cfg.jobs,
                &c,
                &mut on_round,
            )?;
            observe(JobEvent::Trial { trial: t, estimates: &ests });
            per_trial.push(ests);
        }
        Ok(per_trial)
    }
}

/// Await submitted trial handles in submission order, emitting a
/// [`JobEvent::Trial`] per finished trial.
fn wait_trials(
    handles: Vec<MultiHandle>,
    observe: &mut dyn FnMut(JobEvent<'_>),
) -> Result<Vec<Vec<Estimate>>> {
    let mut per_trial = Vec::with_capacity(handles.len());
    for (t, h) in handles.into_iter().enumerate() {
        let ests = h.wait()?;
        observe(JobEvent::Trial {
            trial: t as u32,
            estimates: &ests,
        });
        per_trial.push(ests);
    }
    Ok(per_trial)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::JobConfig;

    fn session() -> Session {
        Session::builder().emulated().build().unwrap()
    }

    #[test]
    fn class_checks_are_typed() {
        let s = session();
        let mut cfg = JobConfig::from_json_text(
            &JobConfig::example_json_functional(),
        )
        .unwrap();
        cfg.target_rel_err = Some(0.01);
        let err = s.run_job(&cfg).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<Error>(),
            Some(Error::InapplicableOption {
                class: "functional",
                ..
            })
        ));
        let mut cfg =
            JobConfig::from_json_text(&JobConfig::example_json_normal())
                .unwrap();
        cfg.trials = 3;
        let err = s.run_job(&cfg).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<Error>(),
            Some(Error::InapplicableOption {
                option: "trials",
                class: "normal",
            })
        ));
    }

    #[test]
    fn multifunctions_job_matches_builder() {
        let s = session();
        let mut cfg =
            JobConfig::from_json_text(&JobConfig::example_json()).unwrap();
        cfg.samples_per_fn = 1 << 10;
        cfg.trials = 2;
        let out = s.run_job(&cfg).unwrap();
        assert_eq!(out.per_trial.len(), 2);
        assert!(out.normal.is_none());
        let want = s
            .multifunctions(&cfg.jobs)
            .samples(cfg.samples_per_fn)
            .seed(cfg.seed)
            .run_trials(2)
            .unwrap();
        assert_eq!(out.per_trial, want);
    }

    #[test]
    fn adaptive_job_streams_rounds_and_matches_builder() {
        let s = session();
        let mut cfg =
            JobConfig::from_json_text(&JobConfig::example_json()).unwrap();
        cfg.samples_per_fn = 1 << 12;
        cfg.trials = 1;
        cfg.target_rel_err = Some(0.05);
        let mut rounds = 0usize;
        let mut last: Vec<Estimate> = vec![];
        let mut finals = 0usize;
        let out = s
            .run_job_observed(&cfg, &mut |ev| match ev {
                JobEvent::Round { estimates, .. } => {
                    rounds += 1;
                    last = estimates.to_vec();
                }
                JobEvent::Trial { .. } => finals += 1,
            })
            .unwrap();
        assert!(rounds >= 1, "at least the pilot streams");
        assert_eq!(finals, 1);
        // the last observed snapshot IS the final result
        assert_eq!(last, out.per_trial[0]);
        // and the whole run matches the fluent builder bit-for-bit
        let want = s
            .multifunctions(&cfg.jobs)
            .samples(cfg.samples_per_fn)
            .seed(cfg.seed)
            .target_rel_err(0.05)
            .run()
            .unwrap();
        assert_eq!(out.per_trial[0], want);
    }

    #[test]
    fn functional_and_normal_jobs_run() {
        let s = session();
        let mut cfg = JobConfig::from_json_text(
            &JobConfig::example_json_functional(),
        )
        .unwrap();
        cfg.samples_per_fn = 1 << 10;
        let out = s.run_job(&cfg).unwrap();
        assert_eq!(out.per_trial.len(), 1);
        assert_eq!(out.per_trial[0].len(), 8); // 4 x 2 grid
        let cfg =
            JobConfig::from_json_text(&JobConfig::example_json_normal())
                .unwrap();
        let out = s.run_job(&cfg).unwrap();
        let n = out.normal.expect("tree diagnostics");
        assert_eq!(out.per_trial[0][0], n.estimate);
    }

    #[test]
    fn event_frames_follow_the_wire_shape() {
        let e = Estimate {
            value: 1.5,
            std_err: 0.25,
            n_samples: 64,
            rounds: 2,
        };
        let ests = [e, e];
        let frames =
            JobEvent::Round { trial: 3, round: 2, estimates: &ests }
                .frames();
        assert_eq!(frames.len(), 2);
        assert_eq!(frames[1].get("fn").and_then(Json::as_i64), Some(1));
        assert_eq!(
            frames[1].get("trial").and_then(Json::as_i64),
            Some(3)
        );
        assert_eq!(
            frames[1].get("round").and_then(Json::as_i64),
            Some(2)
        );
        assert!(frames[1].get("final").is_none());
        assert_eq!(Estimate::from_json(&frames[0]).unwrap(), e);
        let fin =
            JobEvent::Trial { trial: 0, estimates: &ests }.frames();
        assert!(matches!(fin[0].get("final"), Some(Json::Bool(true))));
        assert!(fin[0].get("round").is_none());
    }
}
