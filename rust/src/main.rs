//! `zmc` — CLI for the ZMCintegral-v5.1 reproduction.
//!
//! Subcommands:
//! * `info` — list loaded artifacts and ABI constants
//! * `integrate` — one integral from an expression string
//! * `run` — a JSON job file of any class (multifunction batch,
//!   functional parameter grid, or normal tree search); `--json`
//!   streams the wire frames instead of tables
//! * `serve` — HTTP front end: the same job files over `POST /v1/jobs`
//!   with streamed progress, recall, metrics, and restart replay
//! * `worker` — host an engine behind a TCP accept loop for multi-host
//!   clusters; clients join it with `--remote host:port`
//! * `scan` — parameter-grid sweep of one integrand
//! * `normal` — stratified + tree-search integration
//! * `fig1` — reproduce the paper's Fig. 1 table
//! * `init-config` — write an example job file (`--class` picks which)
//!
//! Every device subcommand builds one [`Session`] — the library's
//! single front door — and drives its class through the session's
//! fluent builders.
//!
//! Examples:
//! ```text
//! zmc integrate --expr "sin(x1)*x2" --bounds "0,3.1416;0,1" --samples 1e6
//! zmc fig1 --n 100 --samples 1000000 --trials 10 --workers 1
//! zmc run --config job.json
//! ```

use std::collections::HashMap;

use anyhow::{anyhow, bail, Context, Result};

use zmc::analytic;
use zmc::config::{JobClass, JobConfig};
use zmc::integrator::harmonic::HarmonicBatch;
use zmc::integrator::{functional, spec::IntegralJob};
use zmc::runtime::ExecTier;
use zmc::serve::{ServeConfig, Server};
use zmc::session::{ErrorPayload, JobOutput, Session};
use zmc::stats::Welford;
use zmc::util::json::Json;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        print_help();
        return Ok(());
    };
    let flags = Flags::parse(&args[1..])?;
    match cmd.as_str() {
        "info" => cmd_info(&flags),
        "integrate" => cmd_integrate(&flags),
        "run" => cmd_run(&flags),
        "serve" => cmd_serve(&flags),
        "worker" => cmd_worker(&flags),
        "scan" => cmd_scan(&flags),
        "normal" => cmd_normal(&flags),
        "fig1" => cmd_fig1(&flags),
        "init-config" => cmd_init_config(&flags),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `zmc help`)"),
    }
}

fn print_help() {
    println!(
        "zmc {} — multi-function Monte-Carlo integration (ZMCintegral-v5.1 \
         reproduction)

USAGE: zmc <command> [--flag value]...

COMMANDS
  info                          list artifacts + ABI
  integrate --expr E --bounds B one integral
  run --config FILE [--json]    job file (any class: multifunctions,
                                functional grid, or normal tree search);
                                --json streams wire frames, one JSON
                                object per line
  serve [--addr H:P]            HTTP service: POST job files to
                                /v1/jobs on one warm session
  worker --listen H:P           host an engine for remote clusters
                                (join it with --remote H:P)
  scan --expr E --bounds B --grid G   parameter sweep (p0 axis)
  normal --expr E --bounds B    stratified + tree search
  fig1                          reproduce paper Fig. 1
  init-config PATH [--class C]  write an example job file
                                (C: multifunctions|functional|normal)

Every device subcommand builds one Session (artifacts -> device pool
-> persistent engines) and runs its class through the session's
fluent builders; the same API is available as a library
(zmc::session::Session).

COMMON FLAGS
  --artifacts DIR   artifact directory     [artifacts]
  --workers N       simulated devices per engine [1]
  --num-engines N   engines in the cluster (integrate/run/normal) [1]
  --tier T          emulator execution tier: naive|plan|fused [fused]
  --samples N       samples per function   [1048576]
  --trials N        independent repeats    [1]
  --seed N          RNG seed               [2021]
  --bounds \"l,h;l,h\"  per-dimension bounds
  --theta \"a,b,..\"  parameter bindings (p0, p1, ...)

MULTI-ENGINE (integrate/run/normal): --num-engines N shards every
batch contiguously across N persistent engines (disjoint Philox
counter ranges, centralized merge) — results are bit-identical to N=1.

MULTI-HOST (integrate/run/serve): start `zmc worker --listen H:P` on
each remote host, then add --remote H:P,H:P,.. (or a job-file
\"remotes\" array) to join them into the cluster alongside the local
engines. Connections open with a Hello handshake (wire-version range
+ registry digest), so a worker running different artifacts is
rejected with a typed error at connect time. Shards fan out over TCP
with heartbeat death detection; a host that dies mid-round has its
whole shard requeued onto a survivor while a supervisor reconnects
with jittered exponential backoff — a bounced worker rejoins the
shard plan and serves later rounds. Every topology (local, remote,
mixed, mid-bounce) is bit-identical. ZMC_CHAOS=\"drop@0:1,..\" (or
\"seeded:S:N\") injects deterministic transport faults for drills.
  --remote H:P,..   comma-separated zmc worker addresses [none]
  --reconnect-retries N     reconnect attempts before a dead host is
                            abandoned (0 disables) [30]
  --reconnect-backoff-ms N  base reconnect backoff, doubled per
                            attempt with deterministic jitter [100]
worker-specific:
  --listen H:P      bind address for the worker (required)
  --bind-retries N  re-bind attempts when the port is still held by
                    a previous worker instance [10]
  --bind-backoff-ms N  pause between bind attempts [200]

ADAPTIVE (integrate/run): setting an error target switches to the
pilot-then-refine loop — the sample budget flows to the functions that
still dominate the error, stopping each one at its target.
  --target-rel-err E   stop at std_err <= E*|I| per function
  --target-abs-err E   stop at std_err <= E per function
  --max-rounds N       refinement rounds after the pilot [12]

SERVE (zmc serve): a versioned jobs-as-data API over one warm session.
POST /v1/jobs takes the same JSON job files as `zmc run` and streams
progress frames (chunked JSON lines) as rounds/trials finish; results
are bit-identical to `zmc run`. GET /v1/jobs/ID recalls status and
results; /v1/metrics and /v1/healthz report counters and topology.
  --addr H:P        bind address         [127.0.0.1:7311]
  --http-workers N  connection handlers  [4]
  --max-jobs N      jobs in flight before 429 [2]
  --queue-cap N     pending connections before 503 [16]
  --rate-limit R    per-client jobs/sec (burst --rate-burst) [off]
  --state-dir DIR   append-only job journal; on restart finished
                    results are recalled and interrupted jobs re-run
  --max-body N      request-body bound in bytes [1048576]
  --read-timeout-ms N  idle-client read deadline, answered 408
                    (0 disables the slowloris guard) [10000]
  --max-recall N    recall bound in estimates: GET /v1/jobs/ID answers
                    413 when the stored result is larger [1048576]
  --journal-keep N  finished jobs kept when the journal compacts on
                    restart (unfinished jobs always replay) [256]

normal-specific: --divisions K --depth D --sigma-mult S
fig1-specific:   --n N (series length)
",
        env!("CARGO_PKG_VERSION")
    );
}

// ---------------------------------------------------------------- flags

struct Flags(HashMap<String, String>);

/// Flags that take no value (presence = true).
const BOOL_FLAGS: &[&str] = &["json"];

impl Flags {
    fn parse(args: &[String]) -> Result<Flags> {
        let mut m = HashMap::new();
        let mut i = 0;
        // allow one positional argument (used by init-config)
        while i < args.len() {
            if let Some(key) = args[i].strip_prefix("--") {
                if BOOL_FLAGS.contains(&key) {
                    m.insert(key.to_string(), "true".into());
                    i += 1;
                    continue;
                }
                let val = args
                    .get(i + 1)
                    .ok_or_else(|| anyhow!("flag --{key} needs a value"))?;
                m.insert(key.to_string(), val.clone());
                i += 2;
            } else {
                m.insert("_pos".into(), args[i].clone());
                i += 1;
            }
        }
        Ok(Flags(m))
    }

    fn str(&self, key: &str) -> Option<&str> {
        self.0.get(key).map(String::as_str)
    }

    fn bool(&self, key: &str) -> bool {
        self.0.contains_key(key)
    }

    fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.0.get(key) {
            None => Ok(default),
            Some(v) => parse_count(v)
                .with_context(|| format!("bad --{key} '{v}'")),
        }
    }

    fn f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.0.get(key) {
            None => Ok(default),
            Some(v) => {
                v.parse().map_err(|_| anyhow!("bad --{key} '{v}'"))
            }
        }
    }

    fn opt_f64(&self, key: &str) -> Result<Option<f64>> {
        match self.0.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| anyhow!("bad --{key} '{v}'")),
        }
    }

    fn u64(&self, key: &str, default: u64) -> Result<u64> {
        Ok(self.usize(key, default as usize)? as u64)
    }
}

/// Accept `1048576`, `1e6`, `2^20`, `1_000_000`.
fn parse_count(s: &str) -> Result<usize> {
    let s = s.replace('_', "");
    if let Some((b, e)) = s.split_once('^') {
        let b: u32 = b.parse()?;
        let e: u32 = e.parse()?;
        return Ok((b as usize).pow(e));
    }
    if s.contains('e') || s.contains('E') {
        let f: f64 = s.parse()?;
        return Ok(f as usize);
    }
    Ok(s.parse()?)
}

fn parse_bounds(s: &str) -> Result<Vec<(f64, f64)>> {
    s.split(';')
        .map(|pair| {
            let (lo, hi) = pair
                .split_once(',')
                .ok_or_else(|| anyhow!("bounds dim '{pair}' not 'lo,hi'"))?;
            Ok((lo.trim().parse()?, hi.trim().parse()?))
        })
        .collect()
}

fn parse_theta(flags: &Flags) -> Result<Vec<f64>> {
    match flags.str("theta") {
        None => Ok(vec![]),
        Some(s) => s
            .split(',')
            .map(|v| {
                v.trim().parse().map_err(|_| anyhow!("bad theta '{v}'"))
            })
            .collect(),
    }
}

/// Start a [`Session`] builder with the CLI's registry-resolution
/// semantics: an explicit `--artifacts DIR` must load (no silent
/// fallback); the default directory falls back to the in-process CPU
/// emulator registry when its manifest is absent, so the CLI works out
/// of the box. A *present but invalid* artifact set (corrupt manifest,
/// ABI mismatch) is always a hard error — falling back would silently
/// compute against the wrong executables.
fn session_builder(flags: &Flags) -> zmc::session::SessionBuilder {
    match flags.str("artifacts") {
        Some(dir) => Session::builder().artifacts(dir),
        None => {
            let b = Session::builder().artifacts_or_emulator("artifacts");
            if b.will_use_emulator() {
                eprintln!(
                    "note: no artifacts/manifest.json; using the \
                     in-process CPU emulator registry"
                );
            }
            b
        }
    }
}

/// One session per CLI invocation: every subcommand's batches share
/// the same warm workers and executable caches. `--num-engines N > 1`
/// puts a cluster of N engines (each with `workers` workers) behind
/// the same builders, and `--remote H:P,..` joins running `zmc worker`
/// hosts into that cluster — results are bit-identical at any
/// topology.
fn make_session(
    flags: &Flags,
    workers: usize,
    num_engines: usize,
) -> Result<Session> {
    make_session_tiered(flags, workers, num_engines, None)
}

/// `make_session` with a job file's execution tier, remote list, and
/// reconnect tuning as the fallback when the corresponding flags are
/// absent (CLI wins, file second, transport default last).
fn make_session_tiered(
    flags: &Flags,
    workers: usize,
    num_engines: usize,
    file: Option<&JobConfig>,
) -> Result<Session> {
    let mut b =
        session_builder(flags).workers(workers).engines(num_engines);
    let remotes = parse_remotes(flags).unwrap_or_else(|| {
        file.map(|c| c.remotes.clone()).unwrap_or_default()
    });
    b = b.remote_engines(remotes);
    b = b.remote_config(parse_remote_config(flags, file)?);
    if let Some(t) = parse_tier(flags)?.or(file.and_then(|c| c.tier)) {
        b = b.execution_tier(t);
    }
    b.build()
}

/// `--reconnect-retries` / `--reconnect-backoff-ms` over the job
/// file's knobs over the default transport tuning (the registry
/// digest and any `ZMC_CHAOS` plan are filled in by the session
/// builder).
fn parse_remote_config(
    flags: &Flags,
    file: Option<&JobConfig>,
) -> Result<zmc::cluster::RemoteConfig> {
    let defaults = zmc::cluster::RemoteConfig::default();
    let file_retries = file.and_then(|c| c.reconnect_retries);
    let file_backoff_ms = file.and_then(|c| c.reconnect_backoff_ms);
    let retries = flags.usize(
        "reconnect-retries",
        file_retries.unwrap_or(defaults.reconnect_retries) as usize,
    )? as u32;
    let backoff = std::time::Duration::from_millis(flags.u64(
        "reconnect-backoff-ms",
        file_backoff_ms
            .unwrap_or(defaults.reconnect_backoff.as_millis() as u64),
    )?);
    Ok(zmc::cluster::RemoteConfig {
        reconnect_retries: retries,
        reconnect: retries > 0,
        reconnect_backoff: backoff,
        ..defaults
    })
}

/// `--remote H:P,H:P,..` → the worker addresses to join; `None` when
/// the flag is absent (so a job file's `remotes` can apply instead).
fn parse_remotes(flags: &Flags) -> Option<Vec<String>> {
    flags.str("remote").map(|s| {
        s.split(',')
            .map(str::trim)
            .filter(|a| !a.is_empty())
            .map(str::to_string)
            .collect()
    })
}

fn parse_tier(flags: &Flags) -> Result<Option<ExecTier>> {
    match flags.str("tier") {
        None => Ok(None),
        Some(s) => ExecTier::parse(s).map(Some).ok_or_else(|| {
            anyhow!("bad --tier '{s}' (expected naive | plan | fused)")
        }),
    }
}

// ------------------------------------------------------------- commands

fn cmd_info(flags: &Flags) -> Result<()> {
    // inspection only: resolve the registry without spawning workers
    let reg = session_builder(flags).load_registry()?;
    println!("artifacts: {}", reg.dir.display());
    println!(
        "ABI: MAX_DIM={} MAX_PROG={} STACK={} MAX_PARAM={}",
        zmc::abi::MAX_DIM,
        zmc::abi::MAX_PROG,
        zmc::abi::STACK,
        zmc::abi::MAX_PARAM
    );
    let tier = parse_tier(flags)?.unwrap_or_else(ExecTier::from_env);
    println!(
        "execution tier: {tier} (select with --tier or ZMC_EMU_TIER; \
         lane width {})",
        zmc::vm::LANES
    );
    println!(
        "ledgers: compiles={} plan_lowers={} plan_hits={} \
         fused_lowers={} fused_hits={} dedup_unique={} dedup_folded={}",
        reg.compile_count(),
        reg.plan_lower_count(),
        reg.plan_hit_count(),
        reg.fused_lower_count(),
        reg.fused_hit_count(),
        reg.dedup_unique_count(),
        reg.dedup_folded_count()
    );
    for e in reg.iter() {
        println!(
            "  {:28} kind={:?} samples={} fns={} cubes={} dims={} tile={}",
            e.name, e.kind, e.samples, e.n_fns, e.n_cubes, e.dims, e.tile
        );
    }
    Ok(())
}

fn cmd_integrate(flags: &Flags) -> Result<()> {
    let expr = flags.str("expr").context("--expr required")?;
    let bounds =
        parse_bounds(flags.str("bounds").context("--bounds required")?)?;
    let theta = parse_theta(flags)?;
    let job = IntegralJob::with_params(expr, &bounds, &theta)?;
    let samples = flags.usize("samples", 1 << 20)?;
    let trials = flags.usize("trials", 1)? as u32;
    let target_rel = flags.opt_f64("target-rel-err")?;
    let target_abs = flags.opt_f64("target-abs-err")?;
    let adaptive = target_rel.is_some() || target_abs.is_some();
    let num_engines = flags.usize("num-engines", 1)?.max(1);
    let session =
        make_session(flags, flags.usize("workers", 1)?, num_engines)?;
    // resolved into one MultiConfig via the builder's escape hatch:
    // passing both targets keeps the free functions' semantics (stop
    // at whichever is met), exactly as previous CLI versions did
    let mcfg = zmc::integrator::multifunctions::MultiConfig {
        samples_per_fn: samples,
        seed: flags.u64("seed", 2021)?,
        target_rel_err: target_rel,
        target_abs_err: target_abs,
        max_rounds: flags.usize("max-rounds", 12)?,
        num_engines,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let per_trial = session
        .multifunctions(std::slice::from_ref(&job))
        .config(mcfg)
        .run_trials(trials)?;
    let dt = t0.elapsed();
    let mut w = Welford::new();
    for t in &per_trial {
        w.push(t[0].value);
    }
    let e = per_trial[0][0];
    println!("integral of: {expr}");
    println!("  domain: {:?}   volume: {}", bounds, job.volume());
    if trials > 1 {
        println!(
            "  I = {:.8} ± {:.3e} (std over {} trials; single-trial \
             σ={:.3e})",
            w.mean(),
            w.std(),
            trials,
            e.std_err
        );
    } else {
        println!("  {e}");
    }
    if adaptive {
        println!(
            "  samples/fn: {} (adaptive, {} rounds)   wall: {:.3}s",
            e.n_samples,
            e.rounds,
            dt.as_secs_f64()
        );
    } else {
        println!(
            "  samples/fn: {}   wall: {:.3}s",
            e.n_samples,
            dt.as_secs_f64()
        );
    }
    Ok(())
}

fn cmd_run(flags: &Flags) -> Result<()> {
    let path = flags.str("config").context("--config required")?;
    let mut cfg = JobConfig::from_file(path)?;
    // CLI flags override the job file; the merged config is the single
    // source of truth — it is what `Session::run_job` validates and
    // runs, so `zmc run` and `POST /v1/jobs` are the same computation
    cfg.workers = flags.usize("workers", cfg.workers)?;
    cfg.num_engines =
        flags.usize("num-engines", cfg.num_engines)?.max(1);
    if let Some(remotes) = parse_remotes(flags) {
        cfg.remotes = remotes;
    }
    cfg.target_rel_err =
        flags.opt_f64("target-rel-err")?.or(cfg.target_rel_err);
    cfg.target_abs_err =
        flags.opt_f64("target-abs-err")?.or(cfg.target_abs_err);
    if flags.str("max-rounds").is_some() {
        cfg.max_rounds = Some(flags.usize("max-rounds", 12)?);
    }
    // one session serves whichever class the job file describes
    let session = make_session_tiered(
        flags,
        cfg.workers,
        cfg.num_engines,
        Some(&cfg),
    )?;
    let t0 = std::time::Instant::now();
    if flags.bool("json") {
        // machine mode: the server's wire frames, one per line
        return match session.run_job_observed(&cfg, &mut |ev| {
            for frame in ev.frames() {
                println!("{frame}");
            }
        }) {
            Ok(_) => {
                println!("{}", run_status_json("done", None));
                Ok(())
            }
            Err(err) => {
                let payload = ErrorPayload::from_error(&err).to_json();
                println!("{}", run_status_json("failed", Some(payload)));
                Err(err)
            }
        };
    }
    let out = session.run_job(&cfg)?;
    let dt = t0.elapsed();
    match &cfg.class {
        JobClass::Multifunctions => {
            print_multifunctions(&session, &cfg, &out.per_trial, dt)
        }
        JobClass::Functional { axes } => {
            print_functional(&cfg, axes, &out.per_trial, dt)
        }
        JobClass::Normal(_) => print_normal_class(&cfg, &out, dt),
    }
    Ok(())
}

/// Terminal line of `zmc run --json`: `{"v":1,"status":..}` (+ error).
fn run_status_json(status: &str, error: Option<Json>) -> Json {
    let mut m = std::collections::BTreeMap::new();
    m.insert("v".to_string(), Json::Num(1.0));
    m.insert("status".to_string(), Json::Str(status.to_string()));
    if let Some(e) = error {
        m.insert("error".to_string(), e);
    }
    Json::Obj(m)
}

fn print_multifunctions(
    session: &Session,
    cfg: &JobConfig,
    per_trial: &[Vec<zmc::integrator::spec::Estimate>],
    dt: std::time::Duration,
) {
    let adaptive =
        cfg.target_rel_err.is_some() || cfg.target_abs_err.is_some();
    println!(
        "{} functions x {} trials x {} samples on {} engine(s) x {} \
         worker(s), tier={}: {:.3}s",
        cfg.jobs.len(),
        cfg.trials,
        cfg.samples_per_fn,
        cfg.num_engines,
        cfg.workers,
        session.execution_tier(),
        dt.as_secs_f64()
    );
    println!("engine: {}", session.engine().metrics().summary());
    if adaptive {
        println!(
            "{:>4}  {:>14}  {:>12}  {:>6}  {:>12}  expr",
            "fn", "mean", "std", "rounds", "samples"
        );
    } else {
        println!("{:>4}  {:>14}  {:>12}  expr", "fn", "mean", "std");
    }
    for (i, job) in cfg.jobs.iter().enumerate() {
        let mut w = Welford::new();
        for t in per_trial {
            w.push(t[i].value);
        }
        let spread =
            if cfg.trials > 1 { w.std() } else { per_trial[0][i].std_err };
        if adaptive {
            // trials may converge in different rounds: report the worst
            // round count and the mean samples actually spent
            let rounds = per_trial.iter().map(|t| t[i].rounds).max().unwrap_or(0);
            let samples = per_trial.iter().map(|t| t[i].n_samples).sum::<u64>()
                / per_trial.len().max(1) as u64;
            println!(
                "{i:>4}  {:>14.8}  {:>12.3e}  {:>6}  {:>12}  {}",
                w.mean(),
                spread,
                rounds,
                samples,
                job.source
            );
        } else {
            println!(
                "{i:>4}  {:>14.8}  {:>12.3e}  {}",
                w.mean(),
                spread,
                job.source
            );
        }
    }
}

fn print_functional(
    cfg: &JobConfig,
    axes: &[Vec<f64>],
    per_trial: &[Vec<zmc::integrator::spec::Estimate>],
    dt: std::time::Duration,
) {
    let thetas = functional::grid(axes);
    println!(
        "scan of {} over {} grid point(s) x {} trial(s): {:.3}s",
        cfg.jobs[0].source,
        thetas.len(),
        cfg.trials,
        dt.as_secs_f64()
    );
    println!("{:>24}  {:>14}  {:>12}", "theta", "I", "σ");
    for (i, t) in thetas.iter().enumerate() {
        let mut w = Welford::new();
        for tr in per_trial {
            w.push(tr[i].value);
        }
        let spread = if cfg.trials > 1 {
            w.std()
        } else {
            per_trial[0][i].std_err
        };
        println!(
            "{:>24}  {:>14.8}  {:>12.3e}",
            fmt_theta(t),
            w.mean(),
            spread
        );
    }
}

fn fmt_theta(theta: &[f64]) -> String {
    let vals: Vec<String> =
        theta.iter().map(|v| format!("{v:.4}")).collect();
    format!("[{}]", vals.join(", "))
}

fn print_normal_class(
    cfg: &JobConfig,
    out: &JobOutput,
    dt: std::time::Duration,
) {
    println!("tree-search integral of: {}", cfg.jobs[0].source);
    println!("  {}  ({:.3}s)", out.per_trial[0][0], dt.as_secs_f64());
    if let Some(r) = &out.normal {
        println!(
            "  cubes/level: {:?}  flagged/level: {:?}  launches: {}",
            r.cubes_per_level, r.flagged_per_level, r.launches
        );
    }
}

/// `zmc serve`: bind, print the routes, serve until killed. Restart
/// with the same `--state-dir` to recover the journal (finished
/// results recalled, interrupted jobs re-run bit-identically).
fn cmd_serve(flags: &Flags) -> Result<()> {
    let defaults = ServeConfig::default();
    let engines = flags
        .usize("engines", flags.usize("num-engines", 1)?)?
        .max(1);
    let cfg = ServeConfig {
        addr: flags
            .str("addr")
            .unwrap_or(defaults.addr.as_str())
            .to_string(),
        workers: flags.usize("workers", defaults.workers)?,
        engines,
        http_workers: flags
            .usize("http-workers", defaults.http_workers)?
            .max(1),
        max_jobs: flags.usize("max-jobs", defaults.max_jobs)?.max(1),
        queue_cap: flags.usize("queue-cap", defaults.queue_cap)?.max(1),
        rate_limit: flags.opt_f64("rate-limit")?,
        rate_burst: flags.f64("rate-burst", defaults.rate_burst)?,
        state_dir: flags.str("state-dir").map(Into::into),
        artifacts: flags.str("artifacts").map(str::to_string),
        tier: parse_tier(flags)?,
        max_body: flags.usize("max-body", defaults.max_body)?,
        remotes: parse_remotes(flags).unwrap_or_default(),
        read_timeout: std::time::Duration::from_millis(flags.u64(
            "read-timeout-ms",
            defaults.read_timeout.as_millis() as u64,
        )?),
        max_recall: flags.usize("max-recall", defaults.max_recall)?,
        journal_keep: flags
            .usize("journal-keep", defaults.journal_keep)?,
    };
    let journaled = cfg.state_dir.is_some();
    let server = Server::bind(cfg)?;
    let addr = server.local_addr()?;
    println!("zmc serve listening on http://{addr}");
    println!(
        "  POST /v1/jobs  GET /v1/jobs/{{id}}  GET /v1/metrics  \
         GET /v1/healthz"
    );
    if !journaled {
        eprintln!(
            "note: no --state-dir; jobs are not journaled and will \
             not survive a restart"
        );
    }
    server.run()
}

/// `zmc worker`: host one persistent engine behind a TCP accept loop.
/// Clients on other hosts join it into their clusters with
/// `--remote H:P` (or a job-file `"remotes"` entry); the process
/// serves until killed. Emulated registries are deterministic across
/// processes, so a remote shard is bit-identical to a local one.
fn cmd_worker(flags: &Flags) -> Result<()> {
    let listen = flags
        .str("listen")
        .context("--listen H:P required (e.g. --listen 0.0.0.0:7411)")?;
    let workers = flags.usize("workers", 1)?.max(1);
    let reg = session_builder(flags).load_registry()?;
    let mut pool = zmc::runtime::device::DevicePool::new(&reg, workers)?;
    if let Some(t) = parse_tier(flags)? {
        pool = pool.with_tier(t);
    }
    let engine = zmc::engine::Engine::for_pool(&pool)?;
    // a bounced worker may race its predecessor's lingering socket for
    // the port: retry the bind so `kill + restart` on the same address
    // just works
    let bind_retries = flags.usize("bind-retries", 10)?;
    let bind_backoff =
        std::time::Duration::from_millis(flags.u64("bind-backoff-ms", 200)?);
    let mut attempt = 0;
    let listener = loop {
        match std::net::TcpListener::bind(listen) {
            Ok(l) => break l,
            Err(e) if attempt < bind_retries => {
                attempt += 1;
                eprintln!(
                    "note: bind {listen} failed ({e}); \
                     retry {attempt}/{bind_retries}"
                );
                std::thread::sleep(bind_backoff);
            }
            Err(e) => {
                return Err(e).with_context(|| {
                    format!("binding worker listener on {listen}")
                })
            }
        }
    };
    // advertise the registry digest so clients with drifted artifacts
    // are rejected at the handshake instead of computing garbage
    let digest = reg.digest();
    let server = zmc::cluster::serve_worker_with_digest(
        listener, engine, digest,
    )?;
    println!(
        "zmc worker listening on {} ({} device worker(s), registry \
         digest {:#018x})",
        server.addr(),
        workers,
        digest
    );
    println!("  join it with: zmc run --remote {}", server.addr());
    server.join();
    Ok(())
}

fn cmd_scan(flags: &Flags) -> Result<()> {
    let expr = flags.str("expr").context("--expr required")?;
    let bounds =
        parse_bounds(flags.str("bounds").context("--bounds required")?)?;
    // --grid "lo:hi:n" sweeps p0
    let grid_spec = flags.str("grid").context("--grid lo:hi:n required")?;
    let parts: Vec<&str> = grid_spec.split(':').collect();
    if parts.len() != 3 {
        bail!("--grid must be lo:hi:n");
    }
    let (lo, hi, n): (f64, f64, usize) =
        (parts[0].parse()?, parts[1].parse()?, parts[2].parse()?);
    let thetas: Vec<Vec<f64>> = functional::linspace(lo, hi, n)
        .into_iter()
        .map(|v| vec![v])
        .collect();
    let job = IntegralJob::with_params(expr, &bounds, &thetas[0])?;
    let session =
        make_session(flags, flags.usize("workers", 1)?, 1)?;
    let t0 = std::time::Instant::now();
    let ests = session
        .functional(&job, &thetas)
        .samples(flags.usize("samples", 1 << 18)?)
        .seed(flags.u64("seed", 2021)?)
        .run()?;
    println!(
        "scan of {expr} over p0 in [{lo}, {hi}] ({n} points): {:.3}s",
        t0.elapsed().as_secs_f64()
    );
    println!("{:>12}  {:>14}  {:>12}", "p0", "I", "σ");
    for (t, e) in thetas.iter().zip(&ests) {
        println!("{:>12.6}  {:>14.8}  {:>12.3e}", t[0], e.value, e.std_err);
    }
    Ok(())
}

fn cmd_normal(flags: &Flags) -> Result<()> {
    let expr = flags.str("expr").context("--expr required")?;
    let bounds =
        parse_bounds(flags.str("bounds").context("--bounds required")?)?;
    let theta = parse_theta(flags)?;
    let job = IntegralJob::with_params(expr, &bounds, &theta)?;
    let session = make_session(
        flags,
        flags.usize("workers", 1)?,
        flags.usize("num-engines", 1)?.max(1),
    )?;
    let t0 = std::time::Instant::now();
    let r = session
        .normal(&job)
        .divisions(flags.usize("divisions", 4)?)
        .trials(flags.usize("trials", 5)? as u32)
        .sigma_mult(flags.f64("sigma-mult", 1.0)?)
        .depth(flags.usize("depth", 2)?)
        .seed(flags.u64("seed", 2021)?)
        .run()?;
    println!("tree-search integral of: {expr}");
    println!("  {}  ({:.3}s)", r.estimate, t0.elapsed().as_secs_f64());
    println!(
        "  cubes/level: {:?}  flagged/level: {:?}  launches: {}",
        r.cubes_per_level, r.flagged_per_level, r.launches
    );
    Ok(())
}

fn cmd_fig1(flags: &Flags) -> Result<()> {
    let n = flags.usize("n", 100)? as u32;
    let samples = flags.usize("samples", 1 << 20)?;
    let trials = flags.usize("trials", 10)? as u32;
    let session =
        make_session(flags, flags.usize("workers", 1)?, 1)?;
    let batch = HarmonicBatch::fig1(n);
    let t0 = std::time::Instant::now();
    let per_trial = session
        .harmonic(&batch)
        .samples(samples)
        .seed(flags.u64("seed", 2021)?)
        .run_trials(trials)?;
    let dt = t0.elapsed();
    println!(
        "Fig. 1: {n} harmonics, {samples} samples, {trials} trials, \
         {} workers — {:.2}s total ({:.2}s/trial)",
        session.workers(),
        dt.as_secs_f64(),
        dt.as_secs_f64() / trials as f64
    );
    println!(
        "{:>4}  {:>12}  {:>12}  {:>12}  {:>8}",
        "n", "mean", "ΔF (std)", "analytic", "|z|"
    );
    let mut max_z = 0.0f64;
    let mut covered = 0usize;
    for i in 0..n as usize {
        let mut w = Welford::new();
        for t in &per_trial {
            w.push(t[i].value);
        }
        let truth = batch.truth(i);
        let sigma = if trials > 1 { w.std() } else { per_trial[0][i].std_err };
        let z = if sigma > 0.0 {
            (w.mean() - truth).abs() / sigma
        } else {
            0.0
        };
        max_z = max_z.max(z);
        // Fig-1 band criterion: analytic line inside mean ± ΔF
        if (w.mean() - truth).abs() <= sigma * 2.0 {
            covered += 1;
        }
        println!(
            "{:>4}  {:>12.6}  {:>12.3e}  {:>12.6}  {:>8.2}",
            i + 1,
            w.mean(),
            sigma,
            truth,
            z
        );
    }
    println!(
        "coverage: {covered}/{n} inside ±2ΔF band; max |z| = {max_z:.2}"
    );
    let _ = analytic::fig1_truth(1); // keep analytic linked in release
    Ok(())
}

fn cmd_init_config(flags: &Flags) -> Result<()> {
    let path = flags.str("_pos").unwrap_or("job.json");
    let class = flags.str("class").unwrap_or("multifunctions");
    let text = JobConfig::example_json_for(class).ok_or_else(|| {
        anyhow!(
            "unknown --class '{class}' \
             (expected multifunctions | functional | normal)"
        )
    })?;
    std::fs::write(path, text)?;
    println!("wrote example {class} job file to {path}");
    Ok(())
}
