//! `zmc` — CLI for the ZMCintegral-v5.1 reproduction.
//!
//! Subcommands:
//! * `info` — list loaded artifacts and ABI constants
//! * `integrate` — one integral from an expression string
//! * `run` — a multifunction batch from a JSON job file
//! * `scan` — parameter-grid sweep of one integrand
//! * `normal` — stratified + tree-search integration
//! * `fig1` — reproduce the paper's Fig. 1 table
//! * `init-config` — write an example job file
//!
//! Examples:
//! ```text
//! zmc integrate --expr "sin(x1)*x2" --bounds "0,3.1416;0,1" --samples 1e6
//! zmc fig1 --n 100 --samples 1000000 --trials 10 --workers 1
//! zmc run --config job.json
//! ```

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use zmc::analytic;
use zmc::cluster::{DeviceCluster, LaunchExec};
use zmc::config::JobConfig;
use zmc::engine::{DeviceEngine, Engine};
use zmc::integrator::harmonic::{self, HarmonicBatch};
use zmc::integrator::multifunctions::{self, MultiConfig};
use zmc::integrator::normal::{self, NormalConfig};
use zmc::integrator::{functional, spec::IntegralJob};
use zmc::runtime::device::DevicePool;
use zmc::runtime::registry::Registry;
use zmc::stats::Welford;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        print_help();
        return Ok(());
    };
    let flags = Flags::parse(&args[1..])?;
    match cmd.as_str() {
        "info" => cmd_info(&flags),
        "integrate" => cmd_integrate(&flags),
        "run" => cmd_run(&flags),
        "scan" => cmd_scan(&flags),
        "normal" => cmd_normal(&flags),
        "fig1" => cmd_fig1(&flags),
        "init-config" => cmd_init_config(&flags),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `zmc help`)"),
    }
}

fn print_help() {
    println!(
        "zmc {} — multi-function Monte-Carlo integration (ZMCintegral-v5.1 \
         reproduction)

USAGE: zmc <command> [--flag value]...

COMMANDS
  info                          list artifacts + ABI
  integrate --expr E --bounds B one integral
  run --config FILE             multifunction batch from JSON job file
  scan --expr E --bounds B --grid G   parameter sweep (p0 axis)
  normal --expr E --bounds B    stratified + tree search
  fig1                          reproduce paper Fig. 1
  init-config PATH              write an example job file

COMMON FLAGS
  --artifacts DIR   artifact directory     [artifacts]
  --workers N       simulated devices per engine [1]
  --num-engines N   engines in the cluster (integrate/run) [1]
  --samples N       samples per function   [1048576]
  --trials N        independent repeats    [1]
  --seed N          RNG seed               [2021]
  --bounds \"l,h;l,h\"  per-dimension bounds
  --theta \"a,b,..\"  parameter bindings (p0, p1, ...)

MULTI-ENGINE (integrate/run): --num-engines N shards every batch
contiguously across N persistent engines (disjoint Philox counter
ranges, centralized merge) — results are bit-identical to N=1.

ADAPTIVE (integrate/run): setting an error target switches to the
pilot-then-refine loop — the sample budget flows to the functions that
still dominate the error, stopping each one at its target.
  --target-rel-err E   stop at std_err <= E*|I| per function
  --target-abs-err E   stop at std_err <= E per function
  --max-rounds N       refinement rounds after the pilot [12]

normal-specific: --divisions K --depth D --sigma-mult S
fig1-specific:   --n N (series length)
",
        env!("CARGO_PKG_VERSION")
    );
}

// ---------------------------------------------------------------- flags

struct Flags(HashMap<String, String>);

impl Flags {
    fn parse(args: &[String]) -> Result<Flags> {
        let mut m = HashMap::new();
        let mut i = 0;
        // allow one positional argument (used by init-config)
        while i < args.len() {
            if let Some(key) = args[i].strip_prefix("--") {
                let val = args
                    .get(i + 1)
                    .ok_or_else(|| anyhow!("flag --{key} needs a value"))?;
                m.insert(key.to_string(), val.clone());
                i += 2;
            } else {
                m.insert("_pos".into(), args[i].clone());
                i += 1;
            }
        }
        Ok(Flags(m))
    }

    fn str(&self, key: &str) -> Option<&str> {
        self.0.get(key).map(String::as_str)
    }

    fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.0.get(key) {
            None => Ok(default),
            Some(v) => parse_count(v)
                .with_context(|| format!("bad --{key} '{v}'")),
        }
    }

    fn f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.0.get(key) {
            None => Ok(default),
            Some(v) => {
                v.parse().map_err(|_| anyhow!("bad --{key} '{v}'"))
            }
        }
    }

    fn opt_f64(&self, key: &str) -> Result<Option<f64>> {
        match self.0.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| anyhow!("bad --{key} '{v}'")),
        }
    }

    fn u64(&self, key: &str, default: u64) -> Result<u64> {
        Ok(self.usize(key, default as usize)? as u64)
    }
}

/// Accept `1048576`, `1e6`, `2^20`, `1_000_000`.
fn parse_count(s: &str) -> Result<usize> {
    let s = s.replace('_', "");
    if let Some((b, e)) = s.split_once('^') {
        let b: u32 = b.parse()?;
        let e: u32 = e.parse()?;
        return Ok((b as usize).pow(e));
    }
    if s.contains('e') || s.contains('E') {
        let f: f64 = s.parse()?;
        return Ok(f as usize);
    }
    Ok(s.parse()?)
}

fn parse_bounds(s: &str) -> Result<Vec<(f64, f64)>> {
    s.split(';')
        .map(|pair| {
            let (lo, hi) = pair
                .split_once(',')
                .ok_or_else(|| anyhow!("bounds dim '{pair}' not 'lo,hi'"))?;
            Ok((lo.trim().parse()?, hi.trim().parse()?))
        })
        .collect()
}

fn parse_theta(flags: &Flags) -> Result<Vec<f64>> {
    match flags.str("theta") {
        None => Ok(vec![]),
        Some(s) => s
            .split(',')
            .map(|v| {
                v.trim().parse().map_err(|_| anyhow!("bad theta '{v}'"))
            })
            .collect(),
    }
}

/// Load the artifact registry; when the default directory is absent and
/// the CPU emulator backend is compiled in, fall back to the emulated
/// registry so the CLI works out of the box. A *present but invalid*
/// artifact set (corrupt manifest, ABI mismatch) is always a hard error
/// — falling back would silently compute against the wrong executables.
fn load_registry(flags: &Flags) -> Result<Arc<Registry>> {
    let dir = flags.str("artifacts").unwrap_or("artifacts");
    let manifest_missing =
        !std::path::Path::new(dir).join("manifest.json").exists();
    if manifest_missing
        && !cfg!(feature = "pjrt")
        && flags.str("artifacts").is_none()
    {
        eprintln!(
            "note: no {dir}/manifest.json; using the in-process CPU \
             emulator registry"
        );
        return Ok(Arc::new(Registry::emulated()));
    }
    Ok(Arc::new(Registry::load(dir)?))
}

/// One persistent engine per CLI invocation: every subcommand's batches
/// share the same warm workers and executable caches.
fn make_engine(flags: &Flags) -> Result<DeviceEngine> {
    make_engine_n(flags, flags.usize("workers", 1)?)
}

fn make_engine_n(flags: &Flags, workers: usize) -> Result<DeviceEngine> {
    let reg = load_registry(flags)?;
    let pool = DevicePool::new(&reg, workers)?;
    Engine::for_pool(&pool)
}

/// The execution surface `--num-engines` selects: a single persistent
/// engine (N = 1, the default) or a cluster of N engines, each with
/// `--workers` workers. Both sides of the same [`LaunchExec`] trait,
/// so every integrator call is topology-blind.
fn make_exec(
    flags: &Flags,
    workers: usize,
    num_engines: usize,
) -> Result<Box<dyn LaunchExec>> {
    if num_engines <= 1 {
        return Ok(Box::new(make_engine_n(flags, workers)?));
    }
    let reg = load_registry(flags)?;
    let pool = DevicePool::new(&reg, workers)?;
    Ok(Box::new(DeviceCluster::for_pool(&pool, num_engines)?))
}

// ------------------------------------------------------------- commands

fn cmd_info(flags: &Flags) -> Result<()> {
    let reg = load_registry(flags)?;
    println!("artifacts: {}", reg.dir.display());
    println!(
        "ABI: MAX_DIM={} MAX_PROG={} STACK={} MAX_PARAM={}",
        zmc::abi::MAX_DIM,
        zmc::abi::MAX_PROG,
        zmc::abi::STACK,
        zmc::abi::MAX_PARAM
    );
    for e in reg.iter() {
        println!(
            "  {:28} kind={:?} samples={} fns={} cubes={} dims={} tile={}",
            e.name, e.kind, e.samples, e.n_fns, e.n_cubes, e.dims, e.tile
        );
    }
    Ok(())
}

fn cmd_integrate(flags: &Flags) -> Result<()> {
    let expr = flags.str("expr").context("--expr required")?;
    let bounds =
        parse_bounds(flags.str("bounds").context("--bounds required")?)?;
    let theta = parse_theta(flags)?;
    let job = IntegralJob::with_params(expr, &bounds, &theta)?;
    let samples = flags.usize("samples", 1 << 20)?;
    let trials = flags.usize("trials", 1)? as u32;
    let cfg = MultiConfig {
        samples_per_fn: samples,
        seed: flags.u64("seed", 2021)?,
        target_rel_err: flags.opt_f64("target-rel-err")?,
        target_abs_err: flags.opt_f64("target-abs-err")?,
        max_rounds: flags.usize("max-rounds", 12)?,
        num_engines: flags.usize("num-engines", 1)?.max(1),
        ..Default::default()
    };
    // the config's topology request decides the execution surface
    let exec =
        make_exec(flags, flags.usize("workers", 1)?, cfg.num_engines)?;
    let t0 = std::time::Instant::now();
    let per_trial = multifunctions::integrate_trials(
        exec.as_ref(),
        &[job.clone()],
        &cfg,
        trials,
    )?;
    let dt = t0.elapsed();
    let mut w = Welford::new();
    for t in &per_trial {
        w.push(t[0].value);
    }
    let e = per_trial[0][0];
    println!("integral of: {expr}");
    println!("  domain: {:?}   volume: {}", bounds, job.volume());
    if trials > 1 {
        println!(
            "  I = {:.8} ± {:.3e} (std over {} trials; single-trial \
             σ={:.3e})",
            w.mean(),
            w.std(),
            trials,
            e.std_err
        );
    } else {
        println!("  I = {:.8} ± {:.3e}", e.value, e.std_err);
    }
    if cfg.is_adaptive() {
        println!(
            "  samples/fn: {} (adaptive, {} rounds)   wall: {:.3}s",
            e.n_samples,
            e.rounds,
            dt.as_secs_f64()
        );
    } else {
        println!(
            "  samples/fn: {}   wall: {:.3}s",
            e.n_samples,
            dt.as_secs_f64()
        );
    }
    Ok(())
}

fn cmd_run(flags: &Flags) -> Result<()> {
    let path = flags.str("config").context("--config required")?;
    let cfg = JobConfig::from_file(path)?;
    let workers = flags.usize("workers", cfg.workers)?;
    let mcfg = MultiConfig {
        samples_per_fn: cfg.samples_per_fn,
        seed: cfg.seed,
        target_rel_err: flags.opt_f64("target-rel-err")?,
        target_abs_err: flags.opt_f64("target-abs-err")?,
        max_rounds: flags.usize("max-rounds", 12)?,
        num_engines: flags.usize("num-engines", cfg.num_engines)?.max(1),
        ..Default::default()
    };
    // the config's topology request decides the execution surface
    let exec = make_exec(flags, workers, mcfg.num_engines)?;
    let t0 = std::time::Instant::now();
    let per_trial = multifunctions::integrate_trials(
        exec.as_ref(),
        &cfg.jobs,
        &mcfg,
        cfg.trials,
    )?;
    let dt = t0.elapsed();
    println!(
        "{} functions x {} trials x {} samples on {} engine(s) x {} \
         worker(s): {:.3}s",
        cfg.jobs.len(),
        cfg.trials,
        cfg.samples_per_fn,
        mcfg.num_engines,
        workers,
        dt.as_secs_f64()
    );
    if mcfg.is_adaptive() {
        println!(
            "{:>4}  {:>14}  {:>12}  {:>6}  {:>12}  expr",
            "fn", "mean", "std", "rounds", "samples"
        );
    } else {
        println!("{:>4}  {:>14}  {:>12}  expr", "fn", "mean", "std");
    }
    for (i, job) in cfg.jobs.iter().enumerate() {
        let mut w = Welford::new();
        for t in &per_trial {
            w.push(t[i].value);
        }
        let spread =
            if cfg.trials > 1 { w.std() } else { per_trial[0][i].std_err };
        if mcfg.is_adaptive() {
            // trials may converge in different rounds: report the worst
            // round count and the mean samples actually spent
            let rounds = per_trial.iter().map(|t| t[i].rounds).max().unwrap_or(0);
            let samples = per_trial.iter().map(|t| t[i].n_samples).sum::<u64>()
                / per_trial.len().max(1) as u64;
            println!(
                "{i:>4}  {:>14.8}  {:>12.3e}  {:>6}  {:>12}  {}",
                w.mean(),
                spread,
                rounds,
                samples,
                job.source
            );
        } else {
            println!(
                "{i:>4}  {:>14.8}  {:>12.3e}  {}",
                w.mean(),
                spread,
                job.source
            );
        }
    }
    Ok(())
}

fn cmd_scan(flags: &Flags) -> Result<()> {
    let expr = flags.str("expr").context("--expr required")?;
    let bounds =
        parse_bounds(flags.str("bounds").context("--bounds required")?)?;
    // --grid "lo:hi:n" sweeps p0
    let grid_spec = flags.str("grid").context("--grid lo:hi:n required")?;
    let parts: Vec<&str> = grid_spec.split(':').collect();
    if parts.len() != 3 {
        bail!("--grid must be lo:hi:n");
    }
    let (lo, hi, n): (f64, f64, usize) =
        (parts[0].parse()?, parts[1].parse()?, parts[2].parse()?);
    let thetas: Vec<Vec<f64>> = functional::linspace(lo, hi, n)
        .into_iter()
        .map(|v| vec![v])
        .collect();
    let job = IntegralJob::with_params(expr, &bounds, &thetas[0])?;
    let engine = make_engine(flags)?;
    let cfg = MultiConfig {
        samples_per_fn: flags.usize("samples", 1 << 18)?,
        seed: flags.u64("seed", 2021)?,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let ests = functional::scan(&engine, &job, &thetas, &cfg)?;
    println!(
        "scan of {expr} over p0 in [{lo}, {hi}] ({n} points): {:.3}s",
        t0.elapsed().as_secs_f64()
    );
    println!("{:>12}  {:>14}  {:>12}", "p0", "I", "σ");
    for (t, e) in thetas.iter().zip(&ests) {
        println!("{:>12.6}  {:>14.8}  {:>12.3e}", t[0], e.value, e.std_err);
    }
    Ok(())
}

fn cmd_normal(flags: &Flags) -> Result<()> {
    let expr = flags.str("expr").context("--expr required")?;
    let bounds =
        parse_bounds(flags.str("bounds").context("--bounds required")?)?;
    let theta = parse_theta(flags)?;
    let job = IntegralJob::with_params(expr, &bounds, &theta)?;
    let engine = make_engine(flags)?;
    let cfg = NormalConfig {
        initial_divisions: flags.usize("divisions", 4)?,
        n_trials: flags.usize("trials", 5)? as u32,
        sigma_mult: flags.f64("sigma-mult", 1.0)?,
        max_depth: flags.usize("depth", 2)?,
        seed: flags.u64("seed", 2021)?,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let r = normal::integrate(&engine, &job, &cfg)?;
    println!("tree-search integral of: {expr}");
    println!(
        "  I = {:.8} ± {:.3e}  ({} samples, {:.3}s)",
        r.estimate.value,
        r.estimate.std_err,
        r.estimate.n_samples,
        t0.elapsed().as_secs_f64()
    );
    println!(
        "  cubes/level: {:?}  flagged/level: {:?}  launches: {}",
        r.cubes_per_level, r.flagged_per_level, r.launches
    );
    Ok(())
}

fn cmd_fig1(flags: &Flags) -> Result<()> {
    let n = flags.usize("n", 100)? as u32;
    let samples = flags.usize("samples", 1 << 20)?;
    let trials = flags.usize("trials", 10)? as u32;
    let engine = make_engine(flags)?;
    let batch = HarmonicBatch::fig1(n);
    let cfg = MultiConfig {
        samples_per_fn: samples,
        seed: flags.u64("seed", 2021)?,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let per_trial =
        harmonic::integrate_trials(&engine, &batch, &cfg, trials)?;
    let dt = t0.elapsed();
    println!(
        "Fig. 1: {n} harmonics, {samples} samples, {trials} trials, \
         {} workers — {:.2}s total ({:.2}s/trial)",
        engine.n_workers(),
        dt.as_secs_f64(),
        dt.as_secs_f64() / trials as f64
    );
    println!(
        "{:>4}  {:>12}  {:>12}  {:>12}  {:>8}",
        "n", "mean", "ΔF (std)", "analytic", "|z|"
    );
    let mut max_z = 0.0f64;
    let mut covered = 0usize;
    for i in 0..n as usize {
        let mut w = Welford::new();
        for t in &per_trial {
            w.push(t[i].value);
        }
        let truth = batch.truth(i);
        let sigma = if trials > 1 { w.std() } else { per_trial[0][i].std_err };
        let z = if sigma > 0.0 {
            (w.mean() - truth).abs() / sigma
        } else {
            0.0
        };
        max_z = max_z.max(z);
        // Fig-1 band criterion: analytic line inside mean ± ΔF
        if (w.mean() - truth).abs() <= sigma * 2.0 {
            covered += 1;
        }
        println!(
            "{:>4}  {:>12.6}  {:>12.3e}  {:>12.6}  {:>8.2}",
            i + 1,
            w.mean(),
            sigma,
            truth,
            z
        );
    }
    println!(
        "coverage: {covered}/{n} inside ±2ΔF band; max |z| = {max_z:.2}"
    );
    let _ = analytic::fig1_truth(1); // keep analytic linked in release
    Ok(())
}

fn cmd_init_config(flags: &Flags) -> Result<()> {
    let path = flags.str("_pos").unwrap_or("job.json");
    std::fs::write(path, JobConfig::example_json())?;
    println!("wrote example job file to {path}");
    Ok(())
}
