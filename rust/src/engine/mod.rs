//! Persistent execution engine — long-lived device workers, warm
//! executable caches, and concurrent job submission.
//!
//! The original coordinator rebuilt everything per call: each
//! `integrate()` spawned throwaway worker threads, constructed fresh
//! PJRT clients, and recompiled every HLO executable. That serves a
//! single batch fine but makes sustained throughput impossible — the
//! paper's 10³-integrations-in-minutes number depends on keeping
//! accelerators warm across launches (Ray's long-lived actors in
//! ZMCintegral, amortized kernel setup in m-Cubes).
//!
//! This module is the replacement:
//!
//! * [`core::Engine`] — spawns its workers **once**; each owns a
//!   context (a `DeviceRuntime` in production) for the engine lifetime,
//!   so per-worker executable caches stay warm across jobs;
//! * a condvar-backed MPMC task queue — workers sleep when idle instead
//!   of the scheduler's old `yield_now` spin;
//! * [`core::Engine::submit`]` -> `[`core::JobHandle`] — asynchronous
//!   submission; any number of independent job sets can be in flight and
//!   each is awaited on its own handle;
//! * the policy layer ([`crate::coordinator::fault::FaultPlan`],
//!   [`crate::coordinator::progress::Metrics`], bounded retries,
//!   worker-death survival) is engine-scoped, preserving the original
//!   scheduler semantics — which are themselves now implemented as a
//!   one-shot scoped run of this engine's worker loop.
//!
//! See DESIGN.md for the architecture diagram and the fidelity argument
//! for the simulated device pool.

pub mod core;
pub mod device;

pub use self::core::{Backend, Engine, EngineConfig, JobHandle};
pub use self::device::{
    DeviceBackend, DeviceEngine, DeviceHandle, LaunchTask, TaggedOutput,
};
