//! Engine core: the shared condvar-backed MPMC task queue, per-job
//! state, and the worker loop — used in two modes:
//!
//! * **persistent** — [`Engine`] spawns its workers once; each worker
//!   builds its context (a `DeviceRuntime` in production, so its
//!   executable cache stays warm) and serves `submit()`ed jobs for the
//!   process lifetime;
//! * **one-shot** — `coordinator::scheduler::Scheduler::run` drives the
//!   same loop under `std::thread::scope` with borrowed closures, which
//!   keeps the legacy synchronous API and the property tests on exactly
//!   the machinery that runs in production.
//!
//! Workers block on a condvar when the queue is empty (no spin-wait);
//! retries, deterministic fault injection and worker-death survival are
//! the policy layer inherited from the original scheduler: a failed
//! task is requeued up to the job's retry budget, a dead worker's
//! in-hand task is pushed back for its peers, and context-construction
//! failures are recorded in [`Metrics`] and surfaced in the final error
//! of any job that later fails.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, Weak};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Error, Result};

use crate::coordinator::fault::{FaultPlan, Verdict};
use crate::coordinator::progress::Metrics;

/// Poison-tolerant lock. Every critical section in this module (and in
/// the queue/ledger code that reuses these helpers) only mutates state
/// that is consistent at each statement boundary — push/pop a queue
/// entry, bump a counter, set an `Option` — so a panic on another
/// thread while it held the lock leaves repair-safe state behind and
/// must not cascade into poisoning every other worker and the whole
/// server. The panic itself is surfaced separately (through `Metrics`
/// and job failure), never swallowed by this recovery.
pub(crate) fn lock_ok<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Condvar wait with the same poison recovery as [`lock_ok`].
pub(crate) fn wait_ok<'a, T>(
    cv: &Condvar,
    guard: std::sync::MutexGuard<'a, T>,
) -> std::sync::MutexGuard<'a, T> {
    cv.wait(guard)
        .unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Best-effort text of a caught panic payload (panics carry `&str` or
/// `String` in practice).
pub(crate) fn panic_message(
    payload: &(dyn std::any::Any + Send),
) -> String {
    payload
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "non-string panic payload".to_string())
}

/// How a worker executes tasks: context factory plus task runner.
///
/// `Ctx` is created on the worker's own thread and never crosses
/// threads, so it may be `!Send` (the production `DeviceRuntime` holds
/// an `Rc`-based PJRT client).
pub trait Backend {
    type Ctx;
    type Task;
    type Out;

    /// Build the per-worker context; called once per worker thread.
    fn make_ctx(&self, worker: usize) -> Result<Self::Ctx>;

    /// Execute one task on this worker's context.
    fn run(&self, ctx: &Self::Ctx, task: &Self::Task) -> Result<Self::Out>;
}

/// Engine topology + default policy.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub n_workers: usize,
    /// Default per-task retry budget for `submit()` (attempts = 1 + retries).
    pub max_retries: u32,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { n_workers: 1, max_retries: 3 }
    }
}

impl EngineConfig {
    pub fn new(n_workers: usize) -> Self {
        EngineConfig { n_workers, ..Default::default() }
    }
}

/// Mutable per-job state behind the job's own mutex.
struct JobInner<R> {
    results: Vec<Option<R>>,
    attempts: Vec<u32>,
    remaining: usize,
    fatal: Option<String>,
}

/// One submitted job: an ordered task list plus completion state.
pub(crate) struct JobState<T, R> {
    tasks: Vec<T>,
    max_retries: u32,
    /// Set when the job's handle is dropped un-awaited: workers discard
    /// any of its tasks still in flight instead of executing them.
    cancelled: AtomicBool,
    inner: Mutex<JobInner<R>>,
    done_cv: Condvar,
}

impl<T, R> JobState<T, R> {
    pub(crate) fn new(tasks: Vec<T>, max_retries: u32) -> Self {
        let n = tasks.len();
        JobState {
            tasks,
            max_retries,
            cancelled: AtomicBool::new(false),
            inner: Mutex::new(JobInner {
                results: (0..n).map(|_| None).collect(),
                attempts: vec![0; n],
                remaining: n,
                fatal: None,
            }),
            done_cv: Condvar::new(),
        }
    }

    pub(crate) fn n_tasks(&self) -> usize {
        self.tasks.len()
    }

    pub(crate) fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::Relaxed)
    }

    /// Mark the job cancelled and wake any waiter. Taking the inner
    /// lock before notifying closes the lost-wakeup window against a
    /// concurrent `wait()` that just checked the flag.
    pub(crate) fn cancel(&self) {
        self.cancelled.store(true, Ordering::Relaxed);
        drop(lock_ok(&self.inner));
        self.done_cv.notify_all();
    }

    pub(crate) fn is_done(&self) -> bool {
        if self.is_cancelled() {
            return true;
        }
        let inner = lock_ok(&self.inner);
        inner.remaining == 0 || inner.fatal.is_some()
    }

    /// Block until every task succeeded (results in task order) or the
    /// job failed fatally or was cancelled.
    pub(crate) fn wait(&self) -> Result<Vec<R>> {
        let mut inner = lock_ok(&self.inner);
        loop {
            if let Some(msg) = &inner.fatal {
                return Err(Error::msg(msg.clone()));
            }
            if inner.remaining == 0 {
                return Ok(inner
                    .results
                    .iter_mut()
                    .map(|r| r.take().expect("completed job has all results"))
                    .collect());
            }
            if self.is_cancelled() {
                return Err(anyhow!("job was cancelled"));
            }
            inner = wait_ok(&self.done_cv, inner);
        }
    }

    /// Drain results **incrementally in task order**: each result is
    /// handed to `f` as soon as it (and every earlier task) has
    /// finished, instead of accumulating the whole `Vec<R>` first.
    /// This is the engine half of the batch subsystem's streaming
    /// reduction — peak memory is O(tasks-in-flight), not O(job).
    ///
    /// The sink runs outside the job lock (ready results are taken in
    /// batches), so a slow sink never blocks the workers. Results
    /// already sunk are not returned again on error: a fatal failure
    /// or cancellation surfaces as `Err` after whatever ordered prefix
    /// was delivered, and the caller discards its partial fold.
    pub(crate) fn for_each(
        &self,
        f: &mut dyn FnMut(R),
    ) -> Result<()> {
        let n = self.tasks.len();
        let mut next = 0usize;
        let mut batch = Vec::new();
        let mut inner = lock_ok(&self.inner);
        loop {
            while next < n && inner.results[next].is_some() {
                batch.push(
                    inner.results[next]
                        .take()
                        .expect("checked is_some above"),
                );
                next += 1;
            }
            if !batch.is_empty() {
                drop(inner);
                for r in batch.drain(..) {
                    f(r);
                }
                inner = lock_ok(&self.inner);
                continue; // more may have landed while sinking
            }
            if next == n {
                return Ok(());
            }
            if let Some(msg) = &inner.fatal {
                return Err(Error::msg(msg.clone()));
            }
            if self.is_cancelled() {
                return Err(anyhow!("job was cancelled"));
            }
            inner = wait_ok(&self.done_cv, inner);
        }
    }

    /// Mark the job failed (first failure wins) and wake waiters.
    fn fail(&self, msg: String) {
        let mut inner = lock_ok(&self.inner);
        if inner.fatal.is_none() && inner.remaining > 0 {
            inner.fatal = Some(msg);
            drop(inner);
            self.done_cv.notify_all();
        }
    }
}

/// Queue protected state.
struct QueueState<T, R> {
    items: VecDeque<(Arc<JobState<T, R>>, usize)>,
    shutdown: bool,
    /// All workers have exited (before shutdown): the engine is dead.
    dead: bool,
    live_workers: usize,
}

/// State shared between the submitting side and the workers.
pub(crate) struct Shared<T, R> {
    queue: Mutex<QueueState<T, R>>,
    task_cv: Condvar,
}

impl<T, R> Shared<T, R> {
    pub(crate) fn new(n_workers: usize) -> Self {
        Shared {
            queue: Mutex::new(QueueState {
                items: VecDeque::new(),
                shutdown: false,
                dead: false,
                live_workers: n_workers,
            }),
            task_cv: Condvar::new(),
        }
    }

    /// Enqueue every task of `job`; fails if the engine is down.
    pub(crate) fn enqueue(&self, job: &Arc<JobState<T, R>>) -> Result<()> {
        let mut q = lock_ok(&self.queue);
        if q.shutdown {
            return Err(anyhow!("engine is shut down"));
        }
        if q.dead {
            return Err(anyhow!("engine has no live workers"));
        }
        for idx in 0..job.n_tasks() {
            q.items.push_back((Arc::clone(job), idx));
        }
        drop(q);
        self.task_cv.notify_all();
        Ok(())
    }

    /// Ask workers to exit once the queue drains, and wake them all.
    pub(crate) fn begin_shutdown(&self) {
        lock_ok(&self.queue).shutdown = true;
        self.task_cv.notify_all();
    }

    /// Pop the next task, blocking on the condvar while the queue is
    /// empty. `None` means shutdown (queued work is drained first).
    fn next_item(&self) -> Option<(Arc<JobState<T, R>>, usize)> {
        let mut q = lock_ok(&self.queue);
        loop {
            if let Some(item) = q.items.pop_front() {
                return Some(item);
            }
            if q.shutdown {
                return None;
            }
            q = wait_ok(&self.task_cv, q);
        }
    }

    /// Remove every queued entry of `job` (cancellation). Returns how
    /// many entries were dropped; the at-most-one in-hand task per
    /// worker is not touched — its result is discarded on completion.
    pub(crate) fn purge(&self, job: &Arc<JobState<T, R>>) -> u64 {
        let mut q = lock_ok(&self.queue);
        let before = q.items.len();
        q.items.retain(|(j, _)| !Arc::ptr_eq(j, job));
        (before - q.items.len()) as u64
    }

    fn push_front(&self, item: (Arc<JobState<T, R>>, usize)) {
        lock_ok(&self.queue).items.push_front(item);
        self.task_cv.notify_one();
    }

    fn push_back(&self, item: (Arc<JobState<T, R>>, usize)) {
        lock_ok(&self.queue).items.push_back(item);
        self.task_cv.notify_one();
    }
}

/// Format recorded context-construction failures for error messages.
fn context_failure_note(metrics: &Metrics) -> String {
    let errs = metrics.worker_errors();
    if errs.is_empty() {
        String::new()
    } else {
        format!(" (earlier worker failures: {})", errs.join("; "))
    }
}

/// Count one failed attempt on `idx`: requeue within budget, else fail
/// the whole job.
fn requeue_or_abort<T, R>(
    shared: &Shared<T, R>,
    job: &Arc<JobState<T, R>>,
    idx: usize,
    err: &str,
    metrics: &Metrics,
) {
    let attempts = {
        let mut inner = lock_ok(&job.inner);
        inner.attempts[idx] += 1;
        inner.attempts[idx]
    };
    if attempts > job.max_retries {
        job.fail(format!(
            "task {idx} failed after {attempts} attempts: {err}{}",
            context_failure_note(metrics)
        ));
    } else {
        metrics.retry();
        shared.push_back((Arc::clone(job), idx));
    }
}

/// The worker body, shared by the persistent engine and the one-shot
/// scheduler. Returns when shutdown is signalled (after draining the
/// queue), when the fault plan kills this worker, or when context
/// construction fails.
pub(crate) fn worker_loop<B: Backend>(
    w: usize,
    shared: &Shared<B::Task, B::Out>,
    backend: &B,
    fault: &FaultPlan,
    metrics: &Metrics,
) {
    let t_start = Instant::now();
    let ctx = match backend.make_ctx(w) {
        Ok(c) => c,
        Err(e) => {
            // Not fatal while peers are alive: record it so that any job
            // that *does* fail later can surface the root cause.
            metrics.record_worker_error(format!("worker {w}: context: {e}"));
            exit_worker(shared, metrics, None);
            return;
        }
    };
    let mut busy = Duration::ZERO;
    let mut my_attempts: u64 = 0;
    while let Some((job, idx)) = shared.next_item() {
        // Discard leftovers of jobs that already failed or were
        // cancelled (purge races the queue pop, so entries of a
        // cancelled job may still surface here).
        if job.is_cancelled() || lock_ok(&job.inner).fatal.is_some() {
            continue;
        }
        match fault.judge(w, my_attempts) {
            Verdict::WorkerDead => {
                // put the task back for the surviving workers and die
                shared.push_front((job, idx));
                break;
            }
            Verdict::FailAttempt => {
                my_attempts += 1;
                metrics.failure();
                requeue_or_abort(shared, &job, idx, "injected fault", metrics);
                continue;
            }
            Verdict::Proceed => {}
        }
        my_attempts += 1;
        let t0 = Instant::now();
        // A panicking task must not unwind through the worker thread:
        // that would kill the worker silently (no exit_worker
        // bookkeeping — live_workers never reaches 0, so outstanding
        // jobs hang instead of failing) and poison any lock the panic
        // crossed. Catch it and treat it as a failed attempt with the
        // panic text as the error.
        let run = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                backend.run(&ctx, &job.tasks[idx])
            }),
        );
        busy += t0.elapsed();
        match run {
            Ok(Ok(out)) => {
                let mut inner = lock_ok(&job.inner);
                if inner.results[idx].is_none() {
                    inner.results[idx] = Some(out);
                    inner.remaining -= 1;
                    metrics.task_done();
                    drop(inner);
                    // notify per result (not just on the last one) so
                    // incremental drains (`for_each`) wake as each task
                    // lands; `wait()` just rechecks `remaining` and
                    // sleeps again, which is cheap on an uncontended cv
                    job.done_cv.notify_all();
                }
            }
            Ok(Err(e)) => {
                metrics.failure();
                requeue_or_abort(shared, &job, idx, &e.to_string(), metrics);
            }
            Err(payload) => {
                let msg = panic_message(payload.as_ref());
                metrics.failure();
                metrics.record_worker_error(format!(
                    "worker {w}: task {idx} panicked: {msg}"
                ));
                requeue_or_abort(
                    shared,
                    &job,
                    idx,
                    &format!("panicked: {msg}"),
                    metrics,
                );
            }
        }
    }
    exit_worker(shared, metrics, Some((busy, t_start.elapsed())));
}

/// Bookkeeping for a worker leaving the pool. When the last worker
/// exits, every incomplete job is failed (its unfinished tasks are all
/// back in the queue by the loop's invariants, so draining the queue
/// reaches every such job). This must happen even during shutdown:
/// under a graceful shutdown the queue is empty by the time the last
/// worker leaves, so anything still queued belongs to a job that can
/// never finish (fault-killed workers) and its waiters must be woken.
fn exit_worker<T, R>(
    shared: &Shared<T, R>,
    metrics: &Metrics,
    timing: Option<(Duration, Duration)>,
) {
    if let Some((busy, total)) = timing {
        metrics.record_worker(busy, total);
    }
    let orphans = {
        let mut q = lock_ok(&shared.queue);
        q.live_workers -= 1;
        if q.live_workers == 0 {
            q.dead = true;
            Some(std::mem::take(&mut q.items))
        } else {
            None
        }
    };
    if let Some(items) = orphans {
        for (job, _) in items {
            let remaining = lock_ok(&job.inner).remaining;
            job.fail(format!(
                "all workers exited with {remaining} tasks unfinished{}",
                context_failure_note(metrics)
            ));
        }
    }
}

/// Handle to one submitted job set; results are awaited per-handle, so
/// any number of independent jobs can be in flight on one engine.
///
/// Dropping a handle without awaiting it **cancels** the job: its
/// queued tasks are purged from the engine so they never occupy a
/// worker slot, and the at-most-one in-hand task per worker has its
/// result discarded. Handles that were awaited (or whose job already
/// finished or failed) drop without side effects.
pub struct JobHandle<T, R> {
    job: Arc<JobState<T, R>>,
    shared: Weak<Shared<T, R>>,
    metrics: Arc<Metrics>,
}

impl<T, R> JobHandle<T, R> {
    /// Block until the job finishes; returns results in task order.
    pub fn wait(self) -> Result<Vec<R>> {
        self.job.wait()
    }

    /// Stream results to `sink` **incrementally in task order** as they
    /// complete, without accumulating a `Vec<R>`. Bit-identical fold
    /// order to `wait()` + iterating the returned vec; peak memory is
    /// O(tasks-in-flight). On failure or cancellation the error is
    /// returned after whatever ordered prefix was already sunk — the
    /// caller should discard its partial fold.
    pub fn wait_each(
        self,
        sink: &mut dyn FnMut(R),
    ) -> Result<()> {
        self.job.for_each(sink)
    }

    /// Non-blocking completion probe (done, failed, or cancelled).
    pub fn is_done(&self) -> bool {
        self.job.is_done()
    }

    pub fn n_tasks(&self) -> usize {
        self.job.n_tasks()
    }

    /// Cancel outstanding work explicitly (identical to dropping the
    /// handle un-awaited).
    pub fn cancel(self) {
        drop(self);
    }
}

impl<T, R> Drop for JobHandle<T, R> {
    fn drop(&mut self) {
        // finished, failed, or already cancelled: nothing to clean up
        if self.job.is_done() {
            return;
        }
        self.job.cancel();
        if let Some(shared) = self.shared.upgrade() {
            let purged = shared.purge(&self.job);
            self.metrics.record_cancelled(purged);
        }
    }
}

/// A persistent pool of device workers fed by a shared task queue.
///
/// Workers (and their contexts — in production a `DeviceRuntime` whose
/// compiled-executable cache stays warm) are spawned once at
/// construction and live until the engine is dropped. [`Engine::submit`]
/// is non-blocking and returns a [`JobHandle`]; multiple job sets may be
/// in flight concurrently from any number of threads.
pub struct Engine<B: Backend> {
    shared: Arc<Shared<B::Task, B::Out>>,
    backend: Arc<B>,
    fault: Arc<FaultPlan>,
    metrics: Arc<Metrics>,
    default_retries: u32,
    n_workers: usize,
    workers: Vec<JoinHandle<()>>,
}

impl<B> Engine<B>
where
    B: Backend + Send + Sync + 'static,
    B::Task: Send + Sync + 'static,
    B::Out: Send + 'static,
{
    /// Spawn the worker pool with a fault-free default policy.
    pub fn new(backend: B, cfg: EngineConfig) -> Result<Engine<B>> {
        Engine::with_policy(
            backend,
            cfg,
            Arc::new(FaultPlan::none()),
            Arc::new(Metrics::new()),
        )
    }

    /// Spawn the worker pool with an explicit fault-injection plan and
    /// metrics sink (the scheduler's policy layer, now engine-scoped).
    pub fn with_policy(
        backend: B,
        cfg: EngineConfig,
        fault: Arc<FaultPlan>,
        metrics: Arc<Metrics>,
    ) -> Result<Engine<B>> {
        if cfg.n_workers == 0 {
            return Err(anyhow!("engine needs >= 1 worker"));
        }
        let shared = Arc::new(Shared::new(cfg.n_workers));
        let backend = Arc::new(backend);
        let mut workers = Vec::with_capacity(cfg.n_workers);
        for w in 0..cfg.n_workers {
            let shared = Arc::clone(&shared);
            let backend = Arc::clone(&backend);
            let fault = Arc::clone(&fault);
            let metrics = Arc::clone(&metrics);
            let handle = std::thread::Builder::new()
                .name(format!("zmc-worker-{w}"))
                .spawn(move || {
                    worker_loop(w, &shared, &*backend, &fault, &metrics)
                })
                .map_err(|e| anyhow!("spawning worker {w}: {e}"))?;
            workers.push(handle);
        }
        Ok(Engine {
            shared,
            backend,
            fault,
            metrics,
            default_retries: cfg.max_retries,
            n_workers: cfg.n_workers,
            workers,
        })
    }

    /// Enqueue a job set; returns immediately with its handle.
    pub fn submit(
        &self,
        tasks: Vec<B::Task>,
    ) -> Result<JobHandle<B::Task, B::Out>> {
        self.submit_with_retries(tasks, self.default_retries)
    }

    /// `submit` with a per-job retry budget.
    pub fn submit_with_retries(
        &self,
        tasks: Vec<B::Task>,
        max_retries: u32,
    ) -> Result<JobHandle<B::Task, B::Out>> {
        let job = Arc::new(JobState::new(tasks, max_retries));
        self.shared.enqueue(&job).map_err(|e| {
            anyhow!("{e}{}", context_failure_note(&self.metrics))
        })?;
        Ok(JobHandle {
            job,
            shared: Arc::downgrade(&self.shared),
            metrics: Arc::clone(&self.metrics),
        })
    }

    /// Synchronous convenience: submit then wait.
    pub fn run(&self, tasks: Vec<B::Task>) -> Result<Vec<B::Out>> {
        self.submit(tasks)?.wait()
    }

    pub fn backend(&self) -> &B {
        &self.backend
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn fault(&self) -> &FaultPlan {
        &self.fault
    }

    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    /// True once every worker has exited before shutdown: the engine
    /// can run nothing further and submissions fail. Set *before* the
    /// orphaned jobs are failed, so a job error observed by a caller
    /// already reflects the engine's final state — the cluster layer
    /// uses this to tell a dead engine (requeue the shard elsewhere)
    /// from a healthy engine whose job legitimately failed (surface
    /// the error).
    pub fn is_dead(&self) -> bool {
        lock_ok(&self.shared.queue).dead
    }
}

impl<B: Backend> Drop for Engine<B> {
    /// Graceful shutdown: queued work drains, then workers exit and are
    /// joined, so every outstanding `JobHandle` resolves.
    fn drop(&mut self) {
        self.shared.begin_shutdown();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Mock;

    impl Backend for Mock {
        type Ctx = ();
        type Task = u64;
        type Out = u64;

        fn make_ctx(&self, _w: usize) -> Result<()> {
            Ok(())
        }

        fn run(&self, _ctx: &(), t: &u64) -> Result<u64> {
            Ok(t.wrapping_mul(31).wrapping_add(7))
        }
    }

    fn expect(tasks: &[u64]) -> Vec<u64> {
        tasks.iter().map(|t| t.wrapping_mul(31).wrapping_add(7)).collect()
    }

    #[test]
    fn submit_and_wait_ordered() {
        let e = Engine::new(Mock, EngineConfig::new(4)).unwrap();
        let tasks: Vec<u64> = (0..200).collect();
        let out = e.run(tasks.clone()).unwrap();
        assert_eq!(out, expect(&tasks));
        assert_eq!(e.metrics().done(), 200);
    }

    #[test]
    fn multiple_jobs_in_flight() {
        let e = Engine::new(Mock, EngineConfig::new(3)).unwrap();
        let a: Vec<u64> = (0..50).collect();
        let b: Vec<u64> = (100..140).collect();
        let c: Vec<u64> = (1000..1003).collect();
        let ha = e.submit(a.clone()).unwrap();
        let hb = e.submit(b.clone()).unwrap();
        let hc = e.submit(c.clone()).unwrap();
        // await out of submission order
        assert_eq!(hc.wait().unwrap(), expect(&c));
        assert_eq!(ha.wait().unwrap(), expect(&a));
        assert_eq!(hb.wait().unwrap(), expect(&b));
    }

    #[test]
    fn empty_job_resolves_immediately() {
        let e = Engine::new(Mock, EngineConfig::new(2)).unwrap();
        let h = e.submit(vec![]).unwrap();
        assert!(h.is_done());
        assert!(h.wait().unwrap().is_empty());
    }

    #[test]
    fn drop_resolves_outstanding_handles() {
        let e = Engine::new(Mock, EngineConfig::new(2)).unwrap();
        let tasks: Vec<u64> = (0..500).collect();
        let h = e.submit(tasks.clone()).unwrap();
        drop(e); // graceful: drains the queue before exiting
        assert_eq!(h.wait().unwrap(), expect(&tasks));
    }

    #[test]
    fn engine_rejects_zero_workers() {
        assert!(Engine::new(Mock, EngineConfig::new(0)).is_err());
    }

    #[test]
    fn waited_handles_drop_without_cancellation() {
        let e = Engine::new(Mock, EngineConfig::new(2)).unwrap();
        let h = e.submit((0..50).collect()).unwrap();
        assert_eq!(h.wait().unwrap().len(), 50);
        // handle was consumed by wait(); nothing was cancelled
        assert_eq!(e.metrics().cancelled(), 0);
        let h2 = e.submit((0..5).collect()).unwrap();
        while !h2.is_done() {
            std::thread::yield_now();
        }
        drop(h2); // done-but-unawaited: results lost, nothing purged
        assert_eq!(e.metrics().cancelled(), 0);
    }

    #[test]
    fn cancelled_job_wait_errors() {
        // cancel() on a job that still has queued work must leave any
        // waiter with an error, not a hang — exercised via JobState
        // directly because a JobHandle cannot be both waited and
        // dropped.
        let job = Arc::new(JobState::<u64, u64>::new(vec![1, 2, 3], 0));
        job.cancel();
        assert!(job.is_done());
        assert!(job.wait().unwrap_err().to_string().contains("cancelled"));
    }

    #[test]
    fn dead_workers_fail_outstanding_handles_even_during_shutdown() {
        // regression: a worker fault-killed while shutdown is in
        // progress must still fail (not strand) unfinished jobs
        let e = Engine::with_policy(
            Mock,
            EngineConfig { n_workers: 1, max_retries: 3 },
            Arc::new(FaultPlan::kill(0, 0)),
            Arc::new(Metrics::new()),
        )
        .unwrap();
        let h = match e.submit(vec![1, 2, 3]) {
            Ok(h) => h,
            Err(_) => return, // worker died before the submit: also fine
        };
        drop(e); // may race the worker's death; wait() must not hang
        assert!(h.wait().is_err());
    }

    struct FailCtx;

    impl Backend for FailCtx {
        type Ctx = ();
        type Task = u64;
        type Out = u64;

        fn make_ctx(&self, _w: usize) -> Result<()> {
            Err(anyhow!("no device"))
        }

        fn run(&self, _ctx: &(), t: &u64) -> Result<u64> {
            Ok(*t)
        }
    }

    /// Serializes tests that swap the process-global panic hook, so a
    /// concurrent take/set/restore cannot leave the silencer installed.
    static PANIC_HOOK_LOCK: Mutex<()> = Mutex::new(());

    /// Panics on task 13; everything else follows `Mock`.
    struct PanicThirteen;

    impl Backend for PanicThirteen {
        type Ctx = ();
        type Task = u64;
        type Out = u64;

        fn make_ctx(&self, _w: usize) -> Result<()> {
            Ok(())
        }

        fn run(&self, _ctx: &(), t: &u64) -> Result<u64> {
            assert!(*t != 13, "task 13 exploded");
            Ok(t.wrapping_mul(31).wrapping_add(7))
        }
    }

    #[test]
    fn task_panic_fails_job_without_killing_engine() {
        // silence the default panic-hook backtrace spam for the
        // intentional panics below; the hook is process-global, so
        // take care to restore it
        let _serial = lock_ok(&PANIC_HOOK_LOCK);
        let hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let result = std::panic::catch_unwind(|| {
            let e = Engine::new(
                PanicThirteen,
                EngineConfig { n_workers: 2, max_retries: 0 },
            )
            .unwrap();
            let err = e
                .submit((0..20).collect())
                .unwrap()
                .wait()
                .unwrap_err()
                .to_string();
            assert!(err.contains("panicked"), "{err}");
            assert!(err.contains("task 13 exploded"), "{err}");
            // the panic is surfaced through Metrics, and the engine —
            // including both workers — keeps serving jobs
            assert!(!e.metrics().worker_errors().is_empty());
            assert!(!e.is_dead());
            let ok: Vec<u64> = (0..13).collect();
            assert_eq!(e.run(ok.clone()).unwrap(), expect(&ok));
        });
        std::panic::set_hook(hook);
        result.unwrap();
    }

    #[test]
    fn task_panic_is_retried_like_a_failure() {
        // one panic consumes one attempt; with a retry budget the
        // task keeps panicking and the job fails after the budget —
        // the retry counter proves the requeue path ran
        let _serial = lock_ok(&PANIC_HOOK_LOCK);
        let hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let result = std::panic::catch_unwind(|| {
            let e = Engine::new(
                PanicThirteen,
                EngineConfig { n_workers: 1, max_retries: 2 },
            )
            .unwrap();
            let err = e
                .submit(vec![13])
                .unwrap()
                .wait()
                .unwrap_err()
                .to_string();
            assert!(err.contains("after 3 attempts"), "{err}");
            assert_eq!(e.metrics().retried(), 2);
        });
        std::panic::set_hook(hook);
        result.unwrap();
    }

    #[test]
    fn all_context_failures_surface_in_job_error() {
        let e = Engine::new(FailCtx, EngineConfig::new(2)).unwrap();
        // whether the submit lands before or after the workers die, the
        // recorded context errors must appear in the failure message
        let err = match e.submit(vec![1, 2, 3]) {
            Ok(h) => h.wait().unwrap_err(),
            Err(err) => err,
        };
        let msg = err.to_string();
        assert!(msg.contains("no device"), "{msg}");
        assert_eq!(e.metrics().worker_errors().len(), 2);
    }
}
