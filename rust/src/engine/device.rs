//! The production backend: engine workers owning [`DeviceRuntime`]s.
//!
//! Every worker thread builds one `DeviceRuntime` when the engine is
//! constructed and keeps it for the engine's lifetime, so HLO
//! executables are compiled **once per worker per executable** no
//! matter how many jobs are submitted — the warm-cache property the
//! paper's long-lived Ray actors provided (asserted by
//! `tests/engine_test.rs`, measured by `benches/engine_warm.rs`).

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::fault::FaultPlan;
use crate::coordinator::progress::Metrics;
use crate::engine::core::{Backend, Engine, EngineConfig, JobHandle};
use crate::runtime::device::{DevicePool, DeviceRuntime};
use crate::runtime::launch::Value;
use crate::runtime::registry::Registry;
use crate::runtime::ExecTier;

/// One device launch: which executable, its input payloads, and an
/// opaque tag the submitter uses to merge results (block/group index).
#[derive(Debug, Clone)]
pub struct LaunchTask {
    pub exe: String,
    pub tag: u64,
    pub inputs: Vec<Value>,
}

/// Output of one launch, tagged for merging.
#[derive(Debug, Clone)]
pub struct TaggedOutput {
    pub tag: u64,
    pub data: Vec<f32>,
    pub device_time: Duration,
}

/// Backend whose worker contexts are per-thread [`DeviceRuntime`]s.
pub struct DeviceBackend {
    registry: Arc<Registry>,
    /// Sink for plan-cache hit/miss events (set when the backend is
    /// built for an engine, so `Metrics::plan_hits/plan_misses` sit
    /// next to the task counters).
    metrics: Option<Arc<Metrics>>,
    /// Emulator execution tier every worker runtime is pinned to;
    /// `None` defers to the process-wide default (`ZMC_EMU_TIER`).
    tier: Option<ExecTier>,
}

impl DeviceBackend {
    pub fn new(registry: Arc<Registry>) -> Self {
        DeviceBackend { registry, metrics: None, tier: None }
    }

    /// Report per-launch plan-cache events into `metrics`.
    pub fn with_metrics(mut self, metrics: &Arc<Metrics>) -> Self {
        self.metrics = Some(Arc::clone(metrics));
        self
    }

    /// Pin every worker runtime to one emulator execution tier.
    pub fn with_tier(mut self, tier: Option<ExecTier>) -> Self {
        self.tier = tier;
        self
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn registry_arc(&self) -> Arc<Registry> {
        Arc::clone(&self.registry)
    }
}

impl Backend for DeviceBackend {
    type Ctx = DeviceRuntime;
    type Task = LaunchTask;
    type Out = TaggedOutput;

    fn make_ctx(&self, _worker: usize) -> Result<DeviceRuntime> {
        #[cfg(not(feature = "pjrt"))]
        if let Some(t) = self.tier {
            return DeviceRuntime::with_tier(Arc::clone(&self.registry), t);
        }
        // Under PJRT programs are lowered on device; the tier is moot.
        #[cfg(feature = "pjrt")]
        let _ = self.tier;
        DeviceRuntime::new(Arc::clone(&self.registry))
    }

    fn run(&self, ctx: &DeviceRuntime, task: &LaunchTask) -> Result<TaggedOutput> {
        let out = ctx.execute(&task.exe, &task.inputs);
        if let Some(m) = &self.metrics {
            let (hits, misses) = ctx.take_plan_events();
            m.record_plan_events(hits, misses);
            let (fhits, fmisses) = ctx.take_fused_events();
            m.record_fused_events(fhits, fmisses);
        }
        out.map(|o| TaggedOutput {
            tag: task.tag,
            data: o.data,
            device_time: o.device_time,
        })
    }
}

/// The engine type every integrator runs on.
pub type DeviceEngine = Engine<DeviceBackend>;

/// Handle to a submitted set of device launches.
pub type DeviceHandle = JobHandle<LaunchTask, TaggedOutput>;

impl Engine<DeviceBackend> {
    /// Spawn a persistent engine over the pool's topology (one worker
    /// thread — one simulated device — per `pool.n_devices`).
    pub fn for_pool(pool: &DevicePool) -> Result<DeviceEngine> {
        let metrics = Arc::new(Metrics::new());
        Engine::with_policy(
            DeviceBackend::new(Arc::clone(&pool.registry))
                .with_metrics(&metrics)
                .with_tier(pool.tier),
            EngineConfig::new(pool.n_devices),
            Arc::new(FaultPlan::none()),
            metrics,
        )
    }

    /// `for_pool` with an explicit fault plan / metrics sink — the
    /// scheduler's fault-injection semantics as an engine policy.
    pub fn for_pool_with(
        pool: &DevicePool,
        max_retries: u32,
        fault: Arc<FaultPlan>,
        metrics: Arc<Metrics>,
    ) -> Result<DeviceEngine> {
        Engine::with_policy(
            DeviceBackend::new(Arc::clone(&pool.registry))
                .with_metrics(&metrics)
                .with_tier(pool.tier),
            EngineConfig { n_workers: pool.n_devices, max_retries },
            fault,
            metrics,
        )
    }

    /// The artifact registry this engine executes from.
    pub fn registry(&self) -> &Registry {
        self.backend().registry()
    }
}
